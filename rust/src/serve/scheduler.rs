//! Continuous virtual-time scheduler over a pool of engine replicas.
//!
//! A discrete-event loop replaces the old FCFS drain: requests become
//! eligible (open-loop arrival or closed-loop release), pass admission
//! control against a per-replica memory ledger, are ordered by a pluggable
//! policy, and occupy a replica slot for their measured service time.
//! Every quantity is virtual-time, so the same seed yields byte-identical
//! results.
//!
//! Structure of one event step (all work at the current clock, then the
//! clock advances to the next completion or arrival):
//!
//! 1. **Completions** — finished sessions release their ledger bytes and,
//!    for closed-loop clients, release the client's next request after its
//!    think time.
//! 2. **Arrivals** — eligible requests enter the waiting queue; requests
//!    whose footprint can never fit a replica are rejected outright.
//! 3. **Admission** — waiting requests are admitted in policy order onto
//!    the least-loaded replica with ledger room (ties prefer free bytes),
//!    until the head of the queue no longer fits anywhere (head-of-line
//!    blocking is deliberate: bypassing it would starve large sessions).
//!    The queue is an incrementally maintained ordered index (a
//!    `BTreeSet` over policy keys) — keys are fixed at eligibility, so
//!    nothing is re-sorted per event.
//! 4. **Dispatch** — each idle replica starts up to
//!    [`SchedulerConfig::max_batch`] of the best admitted sessions as one
//!    co-scheduled batch; service is measured by the [`ServiceModel`]
//!    (batch-capable engines amortize expert loads across the batch, see
//!    [`BatchEngineService`]) and mapped onto the global timeline;
//!    sessions over the preemption budget are truncated at a token
//!    boundary. The batch shrinks inside the engine as members finish,
//!    but the replica re-forms a *new* batch only once all members have
//!    completed — the head-of-line-blocking fairness caveat documented in
//!    DESIGN.md §7.
//!
//! Two executors implement these semantics: the heap-based event core in
//! [`super::events`] (the default, built for million-session runs —
//! DESIGN.md §13) and the original phase-stepped round loop kept here as
//! the equivalence oracle ([`Scheduler::run_round_loop`]).
//! [`SchedulerConfig::core`] selects between them;
//! `rust/tests/event_core_props.rs` pins their outputs bit-identical.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, ensure, Result};

use super::{Request, Slo};
use crate::cluster::{HardwareProfile, Ms, Node};
use crate::control::{ControlConfig, ControlReport};
use crate::coordinator::{BatchEngine, Engine};

/// Queue-ordering policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First come, first served (by eligibility time).
    Fcfs,
    /// Shortest job first, by the token-count service estimate (prompt
    /// length + 8x output tokens: decode dominates service time).
    Sjf,
    /// SLO-aware earliest deadline first: deadline = eligibility +
    /// TTFT budget + TPOT budget x output tokens. Requests without an SLO
    /// have an infinite deadline and fall back to FCFS order.
    Edf,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fcfs" => Policy::Fcfs,
            "sjf" => Policy::Sjf,
            "edf" => Policy::Edf,
            other => bail!("unknown policy {other:?} (fcfs|sjf|edf)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Sjf => "sjf",
            Policy::Edf => "edf",
        }
    }

    /// Total order over waiting requests (smaller = served earlier).
    /// Keys may be infinite (relaxed SLOs) but never NaN — the
    /// `out_tokens == 0` guard avoids `inf * 0` — so sorting with
    /// [`key_cmp`] is a genuine total order.
    pub(crate) fn key(self, r: &Request, eligible_ms: Ms) -> (f64, f64, u64) {
        let primary = match self {
            Policy::Fcfs => eligible_ms,
            Policy::Sjf => (r.prompt.len() + 8 * r.out_tokens) as f64,
            Policy::Edf => {
                let decode_budget = if r.out_tokens == 0 {
                    0.0
                } else {
                    r.slo.tpot_ms * r.out_tokens as f64
                };
                eligible_ms + r.slo.ttft_ms + decode_budget
            }
        };
        (primary, eligible_ms, r.id)
    }
}

fn key_cmp(a: (f64, f64, u64), b: (f64, f64, u64)) -> Ordering {
    a.0.partial_cmp(&b.0)
        .unwrap_or(Ordering::Equal)
        .then(a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal))
        .then(a.2.cmp(&b.2))
}

/// [`Policy::key`] wrapped as a total order so the waiting queue can live
/// in a `BTreeSet` instead of being fully re-sorted inside every
/// admission round (the old `waiting.sort_by` was O(n log n) *per
/// event*). A request's key is fixed once it becomes eligible — policies
/// read only the request and its eligibility time — so the index stays
/// valid across rounds; inserts happen at arrival/re-queue, removals at
/// admission.
///
/// Totality: keys may be `+inf` (relaxed-SLO EDF deadlines) but never
/// NaN (`Policy::key` guards the `inf * 0` case) and never `-0.0` (every
/// input is a non-negative time/count, and products of non-negative
/// finites cannot be negative zero), so [`key_cmp`] — the exact
/// comparator the full sorts used — is antisymmetric and transitive
/// here, and the `BTreeSet` iterates in the same order those sorts
/// produced: `BENCH_serve.json` stays byte-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct QueueKey(f64, f64, u64);

impl QueueKey {
    pub(crate) fn new(k: (f64, f64, u64)) -> Self {
        debug_assert!(!k.0.is_nan() && !k.1.is_nan(), "NaN policy key breaks the total order");
        QueueKey(k.0, k.1, k.2)
    }
}

impl Eq for QueueKey {}

impl PartialOrd for QueueKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueKey {
    fn cmp(&self, other: &Self) -> Ordering {
        key_cmp((self.0, self.1, self.2), (other.0, other.1, other.2))
    }
}

/// Per-session footprint model for admission control, in paper-scale
/// bytes (the same unit as [`Node`]'s ledger): a fixed share (resident
/// expert weights + activation workspace) plus KV bytes per prompt/output
/// token. The tiny-model equivalent is
/// [`crate::engine::kv::session_kv_bytes`].
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Ledger capacity per replica.
    pub budget_bytes: u64,
    pub kv_bytes_per_token: u64,
    pub session_fixed_bytes: u64,
}

impl MemoryModel {
    /// No admission control: every session fits.
    pub fn unlimited() -> Self {
        Self { budget_bytes: u64::MAX, kv_bytes_per_token: 0, session_fixed_bytes: 0 }
    }

    /// Paper-scale footprint from a hardware profile: KV alignment bytes
    /// per token, one resident expert + activation workspace per session.
    pub fn from_profile(p: &HardwareProfile, budget_gb: f64) -> Self {
        Self {
            budget_bytes: (budget_gb * 1e9) as u64,
            kv_bytes_per_token: p.kv_align_bytes as u64,
            session_fixed_bytes: (p.expert_bytes + p.activation_bytes) as u64,
        }
    }

    pub fn session_bytes(&self, r: &Request) -> u64 {
        self.session_fixed_bytes
            + self.kv_bytes_per_token * (r.prompt.len() + r.out_tokens) as u64
    }

    /// `self` with `bytes` carved out of the admission budget up front —
    /// how the tiered cache's per-worker GPU-hot reservation (DESIGN.md
    /// §12) enters admission accounting: hot-resident experts hold their
    /// bytes across tokens, so sessions compete for what remains.
    /// Saturates at zero (an oversized reservation admits nothing rather
    /// than wrapping); a zero reservation is the identity, preserving the
    /// cacheless admission schedule bit for bit.
    pub fn with_reservation(&self, bytes: u64) -> Self {
        Self {
            budget_bytes: self.budget_bytes.saturating_sub(bytes),
            kv_bytes_per_token: self.kv_bytes_per_token,
            session_fixed_bytes: self.session_fixed_bytes,
        }
    }
}

/// Which executor [`Scheduler::run`] drives. Both implement the exact
/// same scheduling semantics (pinned bit-identical by
/// `rust/tests/event_core_props.rs`); they differ only in asymptotics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreKind {
    /// Heap-based event loop ([`super::events`], DESIGN.md §13): O(log n)
    /// per event, preallocated session arena. The default.
    Event,
    /// The original phase-stepped round loop
    /// ([`Scheduler::run_round_loop`]): linear scans per clock step.
    /// Demoted to equivalence oracle and scale-sweep comparison point.
    RoundLoop,
}

impl CoreKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "event" => CoreKind::Event,
            "round-loop" | "round" => CoreKind::RoundLoop,
            other => bail!("unknown scheduler core {other:?} (event|round-loop)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            CoreKind::Event => "event",
            CoreKind::RoundLoop => "round-loop",
        }
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// Replica slots in the pool (each serves one session at a time).
    pub n_replicas: usize,
    pub memory: MemoryModel,
    /// Preempt sessions whose measured service exceeds this virtual
    /// budget: the session is truncated at a token boundary, freeing its
    /// replica and ledger bytes early. Within a batch the truncation is
    /// applied per session to its measured profile (co-batched sessions
    /// keep their measured timings — a conservative approximation, since
    /// an early exit would really shrink the batch and speed them up).
    pub preempt_budget_ms: Option<Ms>,
    /// Sessions a replica may co-schedule per dispatch (1 = sequential,
    /// the behavior of every pre-batching scheduler).
    pub max_batch: usize,
    /// Replica fail-stop injection: (replica index, virtual failure
    /// time). At the failure instant the replica stops serving — its
    /// in-flight batch members and admitted-but-queued sessions return to
    /// the global waiting queue with their ledger bytes released, and it
    /// never admits or dispatches again. Completions due exactly at the
    /// failure instant count as completed (completions process first).
    /// At least one replica must survive to drain outstanding work, else
    /// the run errors out.
    pub replica_failures: Vec<(usize, Ms)>,
    /// Executor backing [`Scheduler::run`].
    pub core: CoreKind,
    /// Sample the queue-depth trace every this many scheduling ticks
    /// (clock steps where work happened). The default of 1 samples every
    /// tick — the historical behavior, byte-identical sweep outputs —
    /// while million-session runs use a wider stride so the trace stays
    /// bounded instead of growing O(events).
    pub queue_sample_stride: usize,
    /// Online SLO control loop (DESIGN.md §15). `None` — the default,
    /// CLI `--control off` — builds no controller at all, the PR 8/9
    /// structural pin: the event core pushes no epoch events, applies no
    /// scaling, and every existing path runs byte-identically in tokens
    /// AND timings. `Some` enables reactive control on the event core
    /// (the round loop stays the uncontrolled oracle and rejects it).
    pub control: Option<ControlConfig>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            policy: Policy::Fcfs,
            n_replicas: 1,
            memory: MemoryModel::unlimited(),
            preempt_budget_ms: None,
            max_batch: 1,
            replica_failures: Vec::new(),
            core: CoreKind::Event,
            queue_sample_stride: 1,
            control: None,
        }
    }
}

/// One session's measured service: what an idle, reset replica does with
/// the request on its own virtual clock.
#[derive(Debug, Clone)]
pub struct SessionProfile {
    pub ttft_ms: Ms,
    pub decode_ms: Ms,
    pub tokens: Vec<u32>,
    pub stall_ms: Ms,
}

impl SessionProfile {
    pub fn service_ms(&self) -> Ms {
        self.ttft_ms + self.decode_ms
    }

    /// Mean decode time per output token after the first (0 when absent).
    pub fn tpot_ms(&self) -> Ms {
        let n = self.tokens.len().saturating_sub(1);
        if n == 0 {
            0.0
        } else {
            self.decode_ms / n as f64
        }
    }
}

/// Where session service times come from.
///
/// Engines are deterministic once `reset`: serving a prompt on replica 3
/// at virtual time T takes exactly as long as serving it on a fresh
/// engine at time 0. The scheduler therefore books *slots* and asks one
/// measuring instance for profiles, instead of cloning heavyweight
/// engines per replica.
pub trait ServiceModel {
    /// Measure serving `req` on an idle, reset replica.
    fn measure(&mut self, req: &Request) -> Result<SessionProfile>;

    /// Measure `reqs` co-scheduled as one batch on an idle, reset
    /// replica; profile times are offsets from the batch's start. The
    /// default has no batching capability: sessions run back to back, so
    /// session `i`'s TTFT includes its predecessors' full services.
    /// Batch-capable models ([`BatchEngineService`],
    /// [`SyntheticService`]) override this with genuinely concurrent
    /// decode. A one-session batch must match [`ServiceModel::measure`].
    fn measure_batch(&mut self, reqs: &[&Request]) -> Result<Vec<SessionProfile>> {
        let mut out = Vec::with_capacity(reqs.len());
        let mut offset: Ms = 0.0;
        for r in reqs {
            let mut p = self.measure(r)?;
            let service = p.service_ms();
            p.ttft_ms += offset;
            offset += service;
            out.push(p);
        }
        Ok(out)
    }

    /// Engine-side batch statistics accumulated since the last call
    /// (`None` for models that do not track any). Used by the
    /// `BENCH_batch.json` sweep to report expert loads per token.
    fn take_stats(&mut self) -> Option<BatchStats> {
        None
    }

    /// Per-expert demand counts accumulated since the last call — the
    /// batched path's load-dedup tallies (how many sessions routed to
    /// each expert, [`crate::coordinator::batch::merge_distinct`]'s
    /// counts summed over iterations). `None` for models that do not
    /// route experts. The SLO control loop (DESIGN.md §15) drains this
    /// each epoch to drive popularity-aware expert replication.
    fn take_expert_demand(&mut self) -> Option<Vec<u64>> {
        None
    }
}

/// Aggregate engine-side statistics over the batches a [`ServiceModel`]
/// measured — the observable that makes load amortization legible:
/// [`BatchStats::loads_per_token`] falls as batches grow.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchStats {
    /// Batches measured (memoized repeats counted — they stand for real
    /// dispatches in the modeled serving run).
    pub batches: u64,
    /// Sessions across those batches.
    pub sessions: u64,
    /// Completed expert loads that fed an expert compute.
    pub expert_loads: u64,
    /// Prediction-driven loads aborted at the gate result.
    pub aborted_loads: u64,
    /// Loads/computes re-booked after a mid-flight node death.
    pub failovers: u64,
    /// Decode tokens produced (prefill tokens excluded).
    pub decode_tokens: u64,
    /// Decode iterations executed (batch-of-N iterations count once).
    pub decode_iterations: u64,
}

impl BatchStats {
    pub fn merge(&mut self, o: &BatchStats) {
        self.batches += o.batches;
        self.sessions += o.sessions;
        self.expert_loads += o.expert_loads;
        self.aborted_loads += o.aborted_loads;
        self.failovers += o.failovers;
        self.decode_tokens += o.decode_tokens;
        self.decode_iterations += o.decode_iterations;
    }

    /// Mean completed expert loads per decode token.
    pub fn loads_per_token(&self) -> f64 {
        if self.decode_tokens == 0 {
            0.0
        } else {
            self.expert_loads as f64 / self.decode_tokens as f64
        }
    }

    /// Mean decode batch size actually achieved.
    pub fn mean_batch(&self) -> f64 {
        if self.decode_iterations == 0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_iterations as f64
        }
    }
}

/// Interns distinct prompts to dense `u32` ids so service memo keys
/// compare in O(1) instead of cloning and comparing a full `Vec<u32>`
/// per lookup. Interning is by content — equal prompts always intern to
/// the same id — so a memo keyed on (interned id, output length) hits
/// exactly when the old (prompt clone, output length) key did; ids
/// merely depend on first-seen order, which the memo never exposes.
#[derive(Debug, Default)]
struct PromptInterner {
    ids: BTreeMap<Vec<u32>, u32>,
}

impl PromptInterner {
    /// Id for `prompt`, allocating one (and the only clone this prompt
    /// will ever cost) on first sight.
    fn intern(&mut self, prompt: &[u32]) -> u32 {
        if let Some(&id) = self.ids.get(prompt) {
            return id;
        }
        let id = self.ids.len() as u32;
        self.ids.insert(prompt.to_vec(), id);
        id
    }
}

/// [`ServiceModel`] backed by a real [`Engine`], memoizing profiles per
/// (interned prompt id, output length) so rate sweeps re-measure each
/// distinct request once.
pub struct EngineService<'e> {
    engine: &'e mut dyn Engine,
    interner: PromptInterner,
    memo: BTreeMap<(u32, usize), SessionProfile>,
}

impl<'e> EngineService<'e> {
    pub fn new(engine: &'e mut dyn Engine) -> Self {
        Self { engine, interner: PromptInterner::default(), memo: BTreeMap::new() }
    }

    pub fn engine_name(&self) -> String {
        self.engine.name()
    }
}

impl ServiceModel for EngineService<'_> {
    fn measure(&mut self, req: &Request) -> Result<SessionProfile> {
        let key = (self.interner.intern(&req.prompt), req.out_tokens);
        if let Some(p) = self.memo.get(&key) {
            return Ok(p.clone());
        }
        self.engine.reset()?;
        let res = self.engine.run_prompt(&req.prompt, req.out_tokens, false)?;
        let p = SessionProfile {
            ttft_ms: res.ttft_ms,
            decode_ms: res.decode_ms,
            tokens: res.tokens,
            stall_ms: res.stall_ms,
        };
        self.memo.insert(key, p.clone());
        Ok(p)
    }
}

/// [`ServiceModel`] backed by a real [`BatchEngine`]: the batched
/// counterpart of [`EngineService`]. Profiles are memoized per batch
/// *composition* (the ordered (prompt, output-length) list), and the
/// engine's load/token tallies accumulate for [`ServiceModel::take_stats`]
/// — memo hits re-count their stored tallies, since a repeated
/// composition stands for a real repeated dispatch in the modeled run.
pub struct BatchEngineService<'e> {
    engine: &'e mut dyn BatchEngine,
    interner: PromptInterner,
    memo: BTreeMap<BatchKey, (Vec<SessionProfile>, BatchStats, Vec<u64>)>,
    stats: BatchStats,
    demand: Vec<u64>,
}

/// Batch composition: the ordered (interned prompt id, output-length)
/// list — the memoization key for batched measurements.
type BatchKey = Vec<(u32, usize)>;

impl<'e> BatchEngineService<'e> {
    pub fn new(engine: &'e mut dyn BatchEngine) -> Self {
        Self {
            engine,
            interner: PromptInterner::default(),
            memo: BTreeMap::new(),
            stats: BatchStats::default(),
            demand: Vec::new(),
        }
    }

    /// Element-wise demand merge (grows on demand; memo hits re-count
    /// their stored vector, same rule as the [`BatchStats`] tallies).
    fn merge_demand(&mut self, d: &[u64]) {
        if d.len() > self.demand.len() {
            self.demand.resize(d.len(), 0);
        }
        for (acc, &v) in self.demand.iter_mut().zip(d) {
            *acc += v;
        }
    }

    pub fn engine_name(&self) -> String {
        self.engine.name()
    }
}

impl ServiceModel for BatchEngineService<'_> {
    fn measure(&mut self, req: &Request) -> Result<SessionProfile> {
        let mut profiles = self.measure_batch(&[req])?;
        Ok(profiles.pop().expect("one profile per session"))
    }

    fn measure_batch(&mut self, reqs: &[&Request]) -> Result<Vec<SessionProfile>> {
        let key: BatchKey =
            reqs.iter().map(|r| (self.interner.intern(&r.prompt), r.out_tokens)).collect();
        if let Some((profiles, tallies, demand)) = self.memo.get(&key) {
            let (tallies, demand, profiles) = (*tallies, demand.clone(), profiles.clone());
            self.stats.merge(&tallies);
            self.merge_demand(&demand);
            return Ok(profiles);
        }
        self.engine.reset()?;
        let sessions: Vec<(&[u32], usize)> =
            reqs.iter().map(|r| (r.prompt.as_slice(), r.out_tokens)).collect();
        let res = self.engine.run_batch(&sessions)?;
        ensure!(res.sessions.len() == reqs.len(), "one result per batched session");
        let profiles: Vec<SessionProfile> = res
            .sessions
            .iter()
            .map(|pr| SessionProfile {
                ttft_ms: pr.ttft_ms,
                decode_ms: pr.decode_ms,
                tokens: pr.tokens.clone(),
                stall_ms: pr.stall_ms,
            })
            .collect();
        let tallies = BatchStats {
            batches: 1,
            sessions: reqs.len() as u64,
            expert_loads: res.expert_loads,
            aborted_loads: res.aborted_loads,
            failovers: res.failovers,
            decode_tokens: res.decode_tokens,
            decode_iterations: res.decode_iterations,
        };
        self.stats.merge(&tallies);
        self.merge_demand(&res.expert_demand);
        self.memo.insert(key, (profiles.clone(), tallies, res.expert_demand));
        Ok(profiles)
    }

    fn take_stats(&mut self) -> Option<BatchStats> {
        Some(std::mem::take(&mut self.stats))
    }

    fn take_expert_demand(&mut self) -> Option<Vec<u64>> {
        if self.demand.iter().all(|&d| d == 0) {
            return None;
        }
        Some(std::mem::take(&mut self.demand))
    }
}

/// Closed-form service model for tests and scheduler studies that do not
/// need the PJRT runtime: TTFT affine in prompt length, constant TPOT.
/// Batched measurement mirrors the engines' shape — prefills serialize,
/// then active sessions share decode iterations whose duration scales by
/// `1 + (B-1) * batch_marginal` (the default marginal of 1.0 means
/// batching buys nothing; see [`SyntheticService::with_batch_marginal`]).
#[derive(Debug, Clone)]
pub struct SyntheticService {
    pub ttft_base_ms: Ms,
    pub ttft_per_prompt_token_ms: Ms,
    pub tpot_ms: Ms,
    /// Marginal cost of each extra co-scheduled session per decode
    /// iteration (0 = perfect amortization, 1 = none).
    pub batch_marginal: f64,
}

impl SyntheticService {
    pub fn new(ttft_base_ms: Ms, ttft_per_prompt_token_ms: Ms, tpot_ms: Ms) -> Self {
        Self { ttft_base_ms, ttft_per_prompt_token_ms, tpot_ms, batch_marginal: 1.0 }
    }

    /// Enable batching benefit: a B-session decode iteration costs
    /// `tpot * (1 + (B-1) * marginal)` instead of `B * tpot`.
    pub fn with_batch_marginal(mut self, marginal: f64) -> Self {
        self.batch_marginal = marginal;
        self
    }

    fn ttft(&self, req: &Request) -> Ms {
        self.ttft_base_ms + self.ttft_per_prompt_token_ms * req.prompt.len() as f64
    }
}

impl ServiceModel for SyntheticService {
    fn measure(&mut self, req: &Request) -> Result<SessionProfile> {
        let n = req.out_tokens.max(1);
        Ok(SessionProfile {
            ttft_ms: self.ttft(req),
            decode_ms: self.tpot_ms * (n - 1) as f64,
            tokens: vec![req.prompt.first().copied().unwrap_or(0); n],
            stall_ms: 0.0,
        })
    }

    fn measure_batch(&mut self, reqs: &[&Request]) -> Result<Vec<SessionProfile>> {
        // Prefills serialize; decode iterations are shared by the active
        // sessions and the batch shrinks as sessions finish — the same
        // shape as `BatchEngine::run_batch`, in closed form.
        let n = reqs.len();
        let mut ttfts = Vec::with_capacity(n);
        let mut clock: Ms = 0.0;
        for r in reqs {
            clock += self.ttft(r);
            ttfts.push(clock);
        }
        let mut remaining: Vec<usize> = reqs.iter().map(|r| r.out_tokens.max(1) - 1).collect();
        let mut finish: Vec<Ms> = ttfts.clone();
        loop {
            let b = remaining.iter().filter(|&&x| x > 0).count();
            if b == 0 {
                break;
            }
            clock += self.tpot_ms * (1.0 + (b as f64 - 1.0) * self.batch_marginal);
            for (i, left) in remaining.iter_mut().enumerate() {
                if *left > 0 {
                    *left -= 1;
                    if *left == 0 {
                        finish[i] = clock;
                    }
                }
            }
        }
        Ok((0..n)
            .map(|i| SessionProfile {
                ttft_ms: ttfts[i],
                decode_ms: finish[i] - ttfts[i],
                tokens: vec![reqs[i].prompt.first().copied().unwrap_or(0); reqs[i].out_tokens.max(1)],
                stall_ms: 0.0,
            })
            .collect())
    }
}

/// How a session ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionOutcome {
    Completed,
    /// Truncated at a token boundary by the preemption budget.
    Preempted,
    /// Refused at admission: footprint exceeds any replica's ledger.
    Rejected,
}

/// Per-session serving record. Latencies reference `eligible_ms` (equal
/// to `arrival_ms` for open-loop requests) — the instant the client was
/// actually waiting from.
#[derive(Debug, Clone)]
pub struct SessionRecord {
    pub id: u64,
    pub tenant: usize,
    /// Replica slot that served the session (`None` if rejected).
    pub replica: Option<usize>,
    pub arrival_ms: Ms,
    pub eligible_ms: Ms,
    pub start_ms: Ms,
    /// Absolute first-token time (None if preempted during prefill or
    /// rejected).
    pub first_token_ms: Option<Ms>,
    pub finish_ms: Ms,
    pub tokens: Vec<u32>,
    pub requested_tokens: usize,
    pub stall_ms: Ms,
    pub slo: Slo,
    pub outcome: SessionOutcome,
}

impl SessionRecord {
    pub fn queued_ms(&self) -> Ms {
        self.start_ms - self.eligible_ms
    }

    /// Time to first token, from eligibility.
    pub fn ttft_ms(&self) -> Option<Ms> {
        self.first_token_ms.map(|t| t - self.eligible_ms)
    }

    pub fn e2e_ms(&self) -> Ms {
        self.finish_ms - self.eligible_ms
    }

    pub fn service_ms(&self) -> Ms {
        self.finish_ms - self.start_ms
    }

    /// Mean decode time per generated token after the first.
    pub fn tpot_ms(&self) -> Option<Ms> {
        let n = self.tokens.len().saturating_sub(1);
        match self.first_token_ms {
            Some(t) if n > 0 => Some((self.finish_ms - t) / n as f64),
            _ => None,
        }
    }

    /// The goodput criterion: completed with TTFT and TPOT within SLO
    /// (a one-token session has no TPOT and passes that half).
    pub fn slo_met(&self) -> bool {
        self.outcome == SessionOutcome::Completed
            && self.ttft_ms().is_some_and(|t| t <= self.slo.ttft_ms)
            && match self.tpot_ms() {
                Some(t) => t <= self.slo.tpot_ms,
                None => true,
            }
    }
}

/// Everything one scheduler run produced.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Records in completion order (finish time, then id).
    pub records: Vec<SessionRecord>,
    pub makespan_ms: Ms,
    /// (time, eligible-but-not-running count) step timeline.
    pub queue_depth: Vec<(Ms, usize)>,
    pub replica_busy_ms: Vec<Ms>,
    /// Per-replica (start, end, request id) service intervals, for
    /// invariant checks. A failed replica's aborted (unfinished)
    /// bookings are removed — only service that actually completed there
    /// remains.
    pub bookings: Vec<Vec<(Ms, Ms, u64)>>,
    /// Sessions whose replica failed under them and that were re-queued
    /// (each re-queue counts once; a session can re-queue repeatedly if
    /// several replicas fail).
    pub requeued: usize,
    /// What the SLO control loop did, costs included (DESIGN.md §15).
    /// `None` whenever [`SchedulerConfig::control`] was `None` — the
    /// uncontrolled outcome is structurally unchanged.
    pub control: Option<ControlReport>,
}

/// Truncate a session at a token boundary when its measured service
/// exceeds the preemption budget. Returns (tokens kept, charged service
/// ms, preempted?).
pub(crate) fn truncate(p: &SessionProfile, budget: Option<Ms>) -> (usize, Ms, bool) {
    let full = p.service_ms();
    let total = p.tokens.len();
    let Some(b) = budget else { return (total, full, false) };
    if full <= b {
        return (total, full, false);
    }
    if p.ttft_ms > b || total == 0 {
        return (0, b.min(full), true);
    }
    let tpot = p.tpot_ms();
    let extra = if tpot <= 0.0 {
        total - 1
    } else {
        (((b - p.ttft_ms) / tpot).floor() as usize).min(total - 1)
    };
    (1 + extra, p.ttft_ms + extra as f64 * tpot, true)
}

struct Replica {
    node: Node,
    /// Admitted (ledger bytes allocated) but not yet running.
    admitted: Vec<usize>,
    /// In-flight sessions of the current batch: (request index, finish
    /// time). At most [`SchedulerConfig::max_batch`] entries; the replica
    /// dispatches a new batch only once all of them completed.
    running: Vec<(usize, Ms)>,
    busy_ms: Ms,
    bookings: Vec<(Ms, Ms, u64)>,
    /// Fail-stopped: never admits or dispatches again.
    dead: bool,
}

/// The continuous scheduler. Stateless: one [`Scheduler::run`] call
/// simulates one complete serving run.
pub struct Scheduler;

impl Scheduler {
    /// Simulate one serving run with the executor selected by
    /// [`SchedulerConfig::core`]. Both executors produce bit-identical
    /// [`ServeOutcome`]s; the event core just gets there in O(log n) per
    /// event.
    pub fn run(
        cfg: &SchedulerConfig,
        service: &mut dyn ServiceModel,
        requests: &[Request],
    ) -> Result<ServeOutcome> {
        if cfg.control.is_some() {
            ensure!(
                cfg.core == CoreKind::Event,
                "--control reactive requires the event core (the round loop is the \
                 uncontrolled equivalence oracle)"
            );
        }
        match cfg.core {
            CoreKind::Event => super::events::run(cfg, service, requests),
            CoreKind::RoundLoop => Self::run_round_loop(cfg, service, requests),
        }
    }

    /// The original phase-stepped executor, kept as the equivalence
    /// oracle for the event core (and as the slow comparison point in
    /// `--scale-sweep`). Scans every replica's running list per clock
    /// step — O(replicas x batch) per event where the event core pays
    /// O(log n).
    pub fn run_round_loop(
        cfg: &SchedulerConfig,
        service: &mut dyn ServiceModel,
        requests: &[Request],
    ) -> Result<ServeOutcome> {
        assert!(cfg.n_replicas > 0, "need at least one replica");
        assert!(cfg.max_batch > 0, "need a positive batch limit");
        let n = requests.len();

        // Closed-loop chains: per client, requests become eligible in id
        // order, each gated behind its predecessor's completion plus think
        // time. Open-loop generators use a unique client per request, so
        // every chain has length one and gating is a no-op.
        let mut chains: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut by_id: Vec<usize> = (0..n).collect();
        by_id.sort_by_key(|&i| requests[i].id);
        for &i in &by_id {
            chains.entry(requests[i].client).or_default().push(i);
        }
        // Next position to release per chain, and the pending-arrival
        // heap (shared with the event core; pops earliest time, ties by
        // id — the order the old sorted-Vec insertion produced).
        let mut chain_pos: BTreeMap<u64, usize> = BTreeMap::new();
        let mut future = super::events::FutureHeap::with_capacity(n);
        for (client, chain) in &chains {
            let idx = chain[0];
            future.push((requests[idx].arrival_ms, requests[idx].id, idx));
            chain_pos.insert(*client, 1);
        }

        let mut fail_at: Vec<Ms> = vec![f64::INFINITY; cfg.n_replicas];
        for &(ri, at) in &cfg.replica_failures {
            ensure!(ri < cfg.n_replicas, "replica failure targets replica {ri} of {}", cfg.n_replicas);
            ensure!(at.is_finite() && at >= 0.0, "bad replica failure time {at}");
            fail_at[ri] = fail_at[ri].min(at);
        }
        let mut reps: Vec<Replica> = (0..cfg.n_replicas)
            .map(|i| Replica {
                node: Node::new(i),
                admitted: Vec::new(),
                running: Vec::new(),
                busy_ms: 0.0,
                bookings: Vec::new(),
                dead: false,
            })
            .collect();
        let mut requeued = 0usize;

        // Waiting queue: an incrementally maintained ordered index over
        // (policy key, request index) — see [`QueueKey`]. Inserted at
        // arrival/re-queue, removed at admission; never re-sorted.
        let mut waiting: BTreeSet<(QueueKey, usize)> = BTreeSet::new();
        let mut eligible_at: Vec<Ms> = vec![0.0; n];
        let mut records: Vec<Option<SessionRecord>> = vec![None; n];
        let mut queue_depth: Vec<(Ms, usize)> = Vec::new();
        let mut clock: Ms = 0.0;
        let mut makespan: Ms = 0.0;
        let mut done = 0usize;
        let stride = cfg.queue_sample_stride.max(1) as u64;
        let mut tick: u64 = 0;

        // Release the next request of `client`'s chain after a completion
        // (or rejection) at time `at`.
        let release_next = |future: &mut super::events::FutureHeap,
                            chain_pos: &mut BTreeMap<u64, usize>,
                            client: u64,
                            at: Ms| {
            let chain = &chains[&client];
            let pos = chain_pos.get_mut(&client).expect("chain position");
            if *pos < chain.len() {
                let idx = chain[*pos];
                *pos += 1;
                let req = &requests[idx];
                let t = req.arrival_ms.max(at + req.think_ms);
                future.push((t, req.id, idx));
            }
        };

        loop {
            // -- 1. completions due at `clock` ---------------------------
            for r in reps.iter_mut() {
                let mut i = 0;
                while i < r.running.len() {
                    let (idx, end) = r.running[i];
                    if end > clock {
                        i += 1;
                        continue;
                    }
                    r.running.remove(i);
                    let req = &requests[idx];
                    let bytes = cfg.memory.session_bytes(req);
                    let freed = r.node.dealloc(bytes);
                    debug_assert_eq!(freed, bytes, "memory ledger drift on request {}", req.id);
                    done += 1;
                    release_next(&mut future, &mut chain_pos, req.client, end);
                }
            }

            // -- 1b. replica failures due at `clock` (after completions:
            // a session finishing exactly at the failure instant counts
            // as completed). Unfinished batch members and admitted
            // sessions re-queue with their ledger bytes released; their
            // eligibility is unchanged, so re-service is policy-ordered.
            for r in reps.iter_mut() {
                let ri = r.node.id;
                if r.dead || fail_at[ri] > clock {
                    continue;
                }
                r.dead = true;
                let mut batch_end = clock;
                for (idx, end) in r.running.drain(..) {
                    batch_end = batch_end.max(end);
                    let bytes = cfg.memory.session_bytes(&requests[idx]);
                    r.node.dealloc(bytes);
                    records[idx] = None;
                    requeued += 1;
                    let key = QueueKey::new(cfg.policy.key(&requests[idx], eligible_at[idx]));
                    waiting.insert((key, idx));
                }
                // The replica was only busy until it died; drop the
                // aborted tail from its utilization and its bookings.
                r.busy_ms -= (batch_end - clock).max(0.0);
                r.bookings.retain(|&(_, end, _)| end <= clock);
                for idx in r.admitted.drain(..) {
                    let bytes = cfg.memory.session_bytes(&requests[idx]);
                    r.node.dealloc(bytes);
                    requeued += 1;
                    let key = QueueKey::new(cfg.policy.key(&requests[idx], eligible_at[idx]));
                    waiting.insert((key, idx));
                }
                // Aborted dispatches may have advanced the makespan past
                // anything that will actually finish; rebuild it from the
                // records that survive.
                makespan = records
                    .iter()
                    .flatten()
                    .filter(|rec| rec.outcome != SessionOutcome::Rejected)
                    .map(|rec| rec.finish_ms)
                    .fold(0.0, f64::max);
            }

            // -- 2. arrivals due at `clock` ------------------------------
            while let Some((t, _, _)) = future.peek() {
                if t > clock {
                    break;
                }
                let (t, _, idx) = future.pop().expect("checked non-empty");
                eligible_at[idx] = t;
                let req = &requests[idx];
                if cfg.memory.session_bytes(req) > cfg.memory.budget_bytes {
                    records[idx] = Some(SessionRecord {
                        id: req.id,
                        tenant: req.tenant,
                        replica: None,
                        arrival_ms: req.arrival_ms,
                        eligible_ms: t,
                        start_ms: t,
                        first_token_ms: None,
                        finish_ms: t,
                        tokens: Vec::new(),
                        requested_tokens: req.out_tokens,
                        stall_ms: 0.0,
                        slo: req.slo,
                        outcome: SessionOutcome::Rejected,
                    });
                    done += 1;
                    release_next(&mut future, &mut chain_pos, req.client, t);
                } else {
                    let key = QueueKey::new(cfg.policy.key(&requests[idx], eligible_at[idx]));
                    waiting.insert((key, idx));
                }
            }

            // -- 3. admission: waiting -> replica ledgers, in index order
            // (the BTreeSet iterates exactly as the old per-round full
            // sort ordered — same comparator, stable keys) ---------------
            while let Some(&(key, idx)) = waiting.first() {
                let bytes = cfg.memory.session_bytes(&requests[idx]);
                // Least-loaded replica with ledger room; ties prefer the
                // most free bytes, then the lowest index. (Load first:
                // with equal free bytes — e.g. no memory limits — the
                // session must still land on an idle replica for the
                // pool to run in parallel.)
                let mut best: Option<(usize, usize, u64)> = None;
                for (ri, r) in reps.iter().enumerate() {
                    if r.dead {
                        continue;
                    }
                    let free = cfg.memory.budget_bytes.saturating_sub(r.node.gpu_bytes_used);
                    if free < bytes {
                        continue;
                    }
                    let load = r.admitted.len() + r.running.len();
                    let better = match best {
                        None => true,
                        Some((_, bl, bf)) => load < bl || (load == bl && free > bf),
                    };
                    if better {
                        best = Some((ri, load, free));
                    }
                }
                let Some((ri, _, _)) = best else { break };
                reps[ri].node.alloc(bytes);
                reps[ri].admitted.push(idx);
                waiting.remove(&(key, idx));
            }

            // -- 4. dispatch: each idle replica starts the globally best
            // admitted sessions, up to `max_batch` of them co-scheduled
            // as one decode batch (work conserving: an idle replica
            // steals admitted-but-queued sessions from its siblings'
            // queues when they fit its own ledger, moving the reservation
            // with them — admission-time binding must not leave a replica
            // idle while work waits elsewhere).
            for ri in 0..reps.len() {
                if reps[ri].dead || !reps[ri].running.is_empty() {
                    continue;
                }
                let mut picked: Vec<usize> = Vec::new();
                while picked.len() < cfg.max_batch {
                    let free_ri =
                        cfg.memory.budget_bytes.saturating_sub(reps[ri].node.gpu_bytes_used);
                    let mut choice: Option<(usize, usize)> = None;
                    let mut choice_key = (0.0, 0.0, 0u64);
                    for qi in 0..reps.len() {
                        for j in 0..reps[qi].admitted.len() {
                            let idx = reps[qi].admitted[j];
                            if qi != ri && cfg.memory.session_bytes(&requests[idx]) > free_ri {
                                continue;
                            }
                            let k = cfg.policy.key(&requests[idx], eligible_at[idx]);
                            if choice.is_none() || key_cmp(k, choice_key) == Ordering::Less {
                                choice = Some((qi, j));
                                choice_key = k;
                            }
                        }
                    }
                    let Some((qi, j)) = choice else { break };
                    let idx = reps[qi].admitted.remove(j);
                    if qi != ri {
                        let bytes = cfg.memory.session_bytes(&requests[idx]);
                        let freed = reps[qi].node.dealloc(bytes);
                        debug_assert_eq!(freed, bytes, "steal ledger drift on request {idx}");
                        reps[ri].node.alloc(bytes);
                    }
                    picked.push(idx);
                }
                if picked.is_empty() {
                    continue;
                }
                let refs: Vec<&Request> = picked.iter().map(|&idx| &requests[idx]).collect();
                let profiles = service.measure_batch(&refs)?;
                ensure!(profiles.len() == picked.len(), "one profile per batched session");
                let start = clock;
                let mut batch_end = start;
                for (profile, &idx) in profiles.iter().zip(&picked) {
                    let req = &requests[idx];
                    let (kept, svc, preempted) = truncate(profile, cfg.preempt_budget_ms);
                    let finish = start + svc;
                    records[idx] = Some(SessionRecord {
                        id: req.id,
                        tenant: req.tenant,
                        replica: Some(ri),
                        arrival_ms: req.arrival_ms,
                        eligible_ms: eligible_at[idx],
                        start_ms: start,
                        first_token_ms: (kept > 0).then_some(start + profile.ttft_ms),
                        finish_ms: finish,
                        tokens: profile.tokens[..kept].to_vec(),
                        requested_tokens: req.out_tokens,
                        stall_ms: profile.stall_ms,
                        slo: req.slo,
                        outcome: if preempted {
                            SessionOutcome::Preempted
                        } else {
                            SessionOutcome::Completed
                        },
                    });
                    reps[ri].running.push((idx, finish));
                    reps[ri].bookings.push((start, finish, req.id));
                    batch_end = batch_end.max(finish);
                    makespan = makespan.max(finish);
                }
                reps[ri].busy_ms += batch_end - start;
            }

            // -- 5. queue-depth sample (every `stride` ticks) ------------
            if tick % stride == 0 {
                let depth = waiting.len() + reps.iter().map(|r| r.admitted.len()).sum::<usize>();
                if queue_depth.last().map(|&(_, d)| d) != Some(depth) {
                    queue_depth.push((clock, depth));
                }
            }
            tick += 1;

            if done >= n {
                break;
            }

            // -- 6. advance virtual time to the next event ---------------
            let mut next = f64::INFINITY;
            if let Some((t, _, _)) = future.peek() {
                next = next.min(t);
            }
            for r in &reps {
                for &(_, end) in &r.running {
                    next = next.min(end);
                }
                if !r.dead {
                    next = next.min(fail_at[r.node.id]);
                }
            }
            if !next.is_finite() {
                // Reachable only when failures killed every replica that
                // could serve the remaining queue; never-fitting requests
                // are rejected at arrival and everything else drains.
                bail!(
                    "scheduler stalled with {} request(s) stuck waiting ({} of {} replica(s) dead)",
                    waiting.len(),
                    reps.iter().filter(|r| r.dead).count(),
                    reps.len()
                );
            }
            clock = next;
        }

        let mut out: Vec<SessionRecord> = records
            .into_iter()
            .map(|r| r.expect("every request resolves to a record"))
            .collect();
        out.sort_by(|a, b| {
            a.finish_ms
                .partial_cmp(&b.finish_ms)
                .unwrap_or(Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        Ok(ServeOutcome {
            records: out,
            makespan_ms: makespan,
            queue_depth,
            replica_busy_ms: reps.iter().map(|r| r.busy_ms).collect(),
            bookings: reps.into_iter().map(|r| r.bookings).collect(),
            requeued,
            control: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: Ms, out: usize) -> Request {
        Request::open_loop(id, vec![1, 2, 3, 4], out, arrival)
    }

    fn svc() -> SyntheticService {
        // service = 10 + 0*prompt + 10*(out-1)
        SyntheticService::new(10.0, 0.0, 10.0)
    }

    /// The ordered waiting index must reproduce the old per-round full
    /// sort exactly: same comparator, same order — including +inf EDF
    /// deadlines and tied eligibilities. This is the equivalence the
    /// byte-identical `BENCH_serve.json` pin rests on.
    #[test]
    fn queue_index_iterates_in_full_sort_order() {
        use crate::model::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..200 {
            let n = 1 + rng.below(24);
            let mut keys: Vec<(QueueKey, usize)> = Vec::with_capacity(n);
            for idx in 0..n {
                // Adversarial key pool: duplicates, zeros, +inf primaries.
                let primary = match rng.below(4) {
                    0 => f64::INFINITY,
                    1 => 0.0,
                    2 => (rng.below(3)) as f64, // forced collisions
                    _ => rng.uniform() * 100.0,
                };
                let eligible = (rng.below(4)) as f64;
                keys.push((QueueKey::new((primary, eligible, idx as u64)), idx));
            }
            let index: BTreeSet<(QueueKey, usize)> = keys.iter().copied().collect();
            let mut sorted = keys.clone();
            sorted.sort_by(|a, b| {
                key_cmp((a.0 .0, a.0 .1, a.0 .2), (b.0 .0, b.0 .1, b.0 .2))
            });
            let from_index: Vec<usize> = index.iter().map(|&(_, idx)| idx).collect();
            let from_sort: Vec<usize> = sorted.iter().map(|&(_, idx)| idx).collect();
            assert_eq!(from_index, from_sort, "case {case}: index order diverged from sort");
        }
    }

    #[test]
    fn prompt_interner_is_stable_by_content() {
        let mut it = PromptInterner::default();
        let a = it.intern(&[1, 2, 3]);
        let b = it.intern(&[4, 5]);
        assert_ne!(a, b);
        assert_eq!(it.intern(&[1, 2, 3]), a, "same prompt, same id");
        assert_eq!(it.intern(&[4, 5]), b);
        assert_ne!(it.intern(&[1, 2]), a, "prefix is a different prompt");
    }

    #[test]
    fn queue_depth_stride_subsamples_the_trace() {
        // Stride 1 (the default) is the historical every-tick trace; a
        // wider stride bounds it by sampling only ticks divisible by the
        // stride. Both cores must agree on the trace at every stride —
        // the ticks they count are the same clock stops.
        let reqs: Vec<Request> = (0..12).map(|i| req(i, i as f64 * 7.0, 3)).collect();
        let mut lens = Vec::new();
        for stride in [1usize, 4] {
            let mut traces = Vec::new();
            for core in [CoreKind::Event, CoreKind::RoundLoop] {
                let cfg =
                    SchedulerConfig { core, queue_sample_stride: stride, ..Default::default() };
                traces.push(Scheduler::run(&cfg, &mut svc(), &reqs).unwrap().queue_depth);
            }
            assert_eq!(traces[0], traces[1], "stride {stride}: cores disagree on the trace");
            lens.push(traces[0].len());
        }
        assert!(lens[1] < lens[0], "stride 4 must drop samples: {lens:?}");
    }

    #[test]
    fn edf_infinite_deadlines_tie_break_deterministically() {
        // Zero-output EDF requests have finite keys; requests without an
        // SLO budget get +inf deadlines and must still serve in
        // (eligibility, id) order through the BTreeSet index.
        let mut reqs: Vec<Request> = (0..4).map(|i| req(i, 0.0, 4)).collect();
        for r in &mut reqs {
            r.slo = Slo::new(f64::INFINITY, f64::INFINITY);
        }
        let cfg = SchedulerConfig { policy: Policy::Edf, ..Default::default() };
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let order: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3], "inf deadlines fall back to FCFS-by-id");
    }

    #[test]
    fn fcfs_single_replica_serializes() {
        let cfg = SchedulerConfig::default();
        let reqs = vec![req(0, 0.0, 4), req(1, 0.0, 4), req(2, 500.0, 4)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        // service = 40 ms each
        assert_eq!(out.records[0].id, 0);
        assert_eq!(out.records[0].queued_ms(), 0.0);
        assert_eq!(out.records[1].queued_ms(), 40.0);
        assert_eq!(out.records[2].queued_ms(), 0.0, "late arrival finds an idle replica");
        assert_eq!(out.makespan_ms, 540.0);
    }

    #[test]
    fn two_replicas_run_in_parallel() {
        let cfg = SchedulerConfig { n_replicas: 2, ..Default::default() };
        let reqs = vec![req(0, 0.0, 4), req(1, 0.0, 4)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert_eq!(out.records[0].queued_ms(), 0.0);
        assert_eq!(out.records[1].queued_ms(), 0.0);
        assert_eq!(out.makespan_ms, 40.0);
    }

    #[test]
    fn dispatch_is_work_conserving_across_replicas() {
        // A (long) and B (short) arrive together and bind to different
        // replicas; C binds behind A. When B's replica idles it must
        // steal C rather than leave it queued behind A.
        let cfg = SchedulerConfig { n_replicas: 2, ..Default::default() };
        let reqs = vec![req(0, 0.0, 19), req(1, 0.0, 1), req(2, 0.0, 1)];
        // services: A = 10 + 18*10 = 190, B = C = 10.
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let c = out.records.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(c.start_ms, 10.0, "C starts when the short replica idles");
        assert_eq!(out.makespan_ms, 190.0);
    }

    #[test]
    fn edf_key_handles_zero_output_tokens() {
        // inf * 0 must not produce a NaN sort key.
        let cfg = SchedulerConfig { policy: Policy::Edf, ..Default::default() };
        let reqs = vec![req(0, 0.0, 0), req(1, 0.0, 4), req(2, 0.0, 0)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert_eq!(out.records.len(), 3);
        assert!(out.records.iter().all(|r| r.outcome == SessionOutcome::Completed));
    }

    #[test]
    fn sjf_prefers_short_jobs() {
        let cfg = SchedulerConfig { policy: Policy::Sjf, ..Default::default() };
        // Long job arrives first but both are waiting when the replica
        // frees: a seed job occupies [0, 40).
        let reqs = vec![req(0, 0.0, 4), req(1, 1.0, 32), req(2, 2.0, 2)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let order: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 2, 1], "short job 2 overtakes long job 1");
    }

    #[test]
    fn edf_prefers_urgent_jobs() {
        let cfg = SchedulerConfig { policy: Policy::Edf, ..Default::default() };
        let mut tight = req(1, 1.0, 4);
        tight.slo = Slo::new(50.0, 10.0);
        let reqs = vec![req(0, 0.0, 4), req(2, 2.0, 4), tight];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let order: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2], "tight-SLO job served before relaxed job 2");
    }

    #[test]
    fn preemption_truncates_at_token_boundary() {
        let cfg = SchedulerConfig { preempt_budget_ms: Some(35.0), ..Default::default() };
        let reqs = vec![req(0, 0.0, 10)]; // full service 10 + 90 = 100 ms
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let r = &out.records[0];
        assert_eq!(r.outcome, SessionOutcome::Preempted);
        // ttft 10, then 2 full tokens of 10 ms fit in the 35 ms budget.
        assert_eq!(r.tokens.len(), 3);
        assert_eq!(r.finish_ms, 30.0);
    }

    #[test]
    fn oversize_requests_are_rejected() {
        let cfg = SchedulerConfig {
            memory: MemoryModel {
                budget_bytes: 100,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 0,
            },
            ..Default::default()
        };
        // 4 prompt + 12 out = 16 tokens -> 160 bytes > 100.
        let reqs = vec![req(0, 0.0, 12), req(1, 0.0, 2)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        let rej = out.records.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(rej.outcome, SessionOutcome::Rejected);
        assert!(rej.tokens.is_empty());
        let ok = out.records.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(ok.outcome, SessionOutcome::Completed);
    }

    #[test]
    fn admission_ledger_limits_in_flight_footprint() {
        // Each session is 60 bytes; budget 100 -> at most one admitted at
        // a time per replica, so the second waits in the global queue.
        let cfg = SchedulerConfig {
            memory: MemoryModel {
                budget_bytes: 100,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 0,
            },
            ..Default::default()
        };
        let reqs = vec![req(0, 0.0, 2), req(1, 0.0, 2)]; // 6 tokens = 60 B each
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert!(out.records.iter().all(|r| r.outcome == SessionOutcome::Completed));
        // Serial anyway on one replica; the point is no ledger overflow.
        assert_eq!(out.records[1].queued_ms(), 20.0);
    }

    #[test]
    fn closed_loop_gates_on_think_time() {
        // One client, two requests, think 100 ms: the second becomes
        // eligible 100 ms after the first completes (service 40 ms).
        let mut a = req(0, 0.0, 4);
        let mut b = req(1, 0.0, 4);
        a.client = 7;
        b.client = 7;
        b.think_ms = 100.0;
        let out = Scheduler::run(&SchedulerConfig::default(), &mut svc(), &[a, b]).unwrap();
        assert_eq!(out.records[1].eligible_ms, 140.0);
        assert_eq!(out.records[1].start_ms, 140.0);
        assert_eq!(out.records[1].queued_ms(), 0.0);
    }

    #[test]
    fn empty_request_list_is_fine() {
        let out = Scheduler::run(&SchedulerConfig::default(), &mut svc(), &[]).unwrap();
        assert!(out.records.is_empty());
        assert_eq!(out.makespan_ms, 0.0);
    }

    #[test]
    fn batch_of_one_matches_sequential_measure() {
        let mut s = SyntheticService::new(10.0, 0.5, 10.0).with_batch_marginal(0.1);
        let r = req(0, 0.0, 6);
        let solo = s.measure(&r).unwrap();
        let batched = s.measure_batch(&[&r]).unwrap().pop().unwrap();
        assert_eq!(solo.ttft_ms, batched.ttft_ms);
        assert_eq!(solo.decode_ms, batched.decode_ms);
        assert_eq!(solo.tokens, batched.tokens);
    }

    #[test]
    fn default_measure_batch_stacks_sequentially() {
        /// Measure-only model: exercises the trait's fallback.
        struct Fixed;
        impl ServiceModel for Fixed {
            fn measure(&mut self, req: &Request) -> Result<SessionProfile> {
                SyntheticService::new(10.0, 0.0, 10.0).measure(req)
            }
        }
        let (a, b) = (req(0, 0.0, 4), req(1, 0.0, 4)); // service 40 ms each
        let profiles = Fixed.measure_batch(&[&a, &b]).unwrap();
        assert_eq!(profiles[0].ttft_ms, 10.0);
        assert_eq!(profiles[1].ttft_ms, 50.0, "no batch capability: b waits out a");
        assert_eq!(profiles[1].service_ms(), 80.0);
    }

    #[test]
    fn dispatch_coschedules_up_to_max_batch() {
        // Three identical requests at t=0, one replica, max_batch 2 with
        // perfect amortization: two start together, the third waits for
        // the whole batch (the §7 head-of-line re-form point).
        let cfg = SchedulerConfig { max_batch: 2, ..Default::default() };
        let reqs = vec![req(0, 0.0, 4), req(1, 0.0, 4), req(2, 0.0, 4)];
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0).with_batch_marginal(0.0);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).unwrap();
        let by_id = |id| out.records.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).start_ms, 0.0);
        assert_eq!(by_id(1).start_ms, 0.0, "co-scheduled with request 0");
        // Prefills serialize (10 + 10), then 3 shared iterations of 10 ms.
        assert_eq!(by_id(0).finish_ms, 50.0);
        assert_eq!(by_id(1).finish_ms, 50.0);
        assert_eq!(by_id(2).start_ms, 50.0, "third waits for the batch to drain");
        assert_eq!(out.makespan_ms, 90.0);
    }

    #[test]
    fn batching_cuts_makespan_under_overload() {
        let reqs: Vec<Request> = (0..8).map(|i| req(i, 0.0, 8)).collect();
        let run = |max_batch| {
            let cfg = SchedulerConfig { max_batch, ..Default::default() };
            let mut svc = SyntheticService::new(10.0, 0.0, 10.0).with_batch_marginal(0.1);
            Scheduler::run(&cfg, &mut svc, &reqs).unwrap().makespan_ms
        };
        let sequential = run(1);
        let batched = run(8);
        assert_eq!(sequential, 640.0);
        // 8 prefills (80 ms) + 7 iterations at 10 * (1 + 7*0.1) = 17 ms.
        assert_eq!(batched, 199.0);
        assert!(batched < sequential);
    }

    #[test]
    fn replica_failure_requeues_and_survivor_completes_everything() {
        // Two replicas, two long jobs dispatched at t=0 (one each).
        // Replica 0 dies at t=15, mid-service: its session re-queues and
        // re-runs on replica 1 after that replica's own job drains.
        let cfg = SchedulerConfig {
            n_replicas: 2,
            replica_failures: vec![(0, 15.0)],
            ..Default::default()
        };
        let reqs = vec![req(0, 0.0, 4), req(1, 0.0, 4)]; // service 40 ms each
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert_eq!(out.requeued, 1);
        assert!(out.records.iter().all(|r| r.outcome == SessionOutcome::Completed));
        // Request 0 (bound to replica 0 first) re-ran on replica 1.
        let r0 = out.records.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r0.replica, Some(1));
        assert_eq!(r0.start_ms, 40.0, "re-served after the survivor drains");
        assert_eq!(r0.finish_ms, 80.0);
        assert_eq!(out.makespan_ms, 80.0);
        // The dead replica keeps no aborted bookings.
        assert!(out.bookings[0].iter().all(|&(_, end, _)| end <= 15.0));
        // Its utilization covers only the span it actually served.
        assert!((out.replica_busy_ms[0] - 15.0).abs() < 1e-9);
    }

    #[test]
    fn completion_at_failure_instant_counts_as_completed() {
        // Service ends exactly when the replica dies: completions are
        // processed first, so nothing re-queues.
        let cfg = SchedulerConfig {
            replica_failures: vec![(0, 40.0)],
            ..Default::default()
        };
        let reqs = vec![req(0, 0.0, 4)]; // service exactly 40 ms
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert_eq!(out.requeued, 0);
        assert_eq!(out.records[0].outcome, SessionOutcome::Completed);
        assert_eq!(out.records[0].finish_ms, 40.0);
    }

    #[test]
    fn failure_releases_admitted_ledger_bytes() {
        // Tight ledger, two sessions bound to the doomed replica (one
        // running, one admitted). Both re-queue and complete on the
        // survivor; a leaked reservation would deadlock the re-admission.
        let cfg = SchedulerConfig {
            n_replicas: 2,
            memory: MemoryModel {
                budget_bytes: 200,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 0,
            },
            replica_failures: vec![(0, 5.0)],
            ..Default::default()
        };
        // 4 prompt + 4 out = 80 bytes each: two fit a replica, barely.
        let reqs = vec![req(0, 0.0, 4), req(1, 0.0, 4), req(2, 0.0, 4)];
        let out = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap();
        assert!(out.requeued >= 1);
        assert!(out.records.iter().all(|r| r.outcome == SessionOutcome::Completed));
        let produced: usize = out.records.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(produced, 12);
    }

    #[test]
    fn all_replicas_dead_with_pending_work_errors() {
        let cfg = SchedulerConfig {
            replica_failures: vec![(0, 5.0)],
            ..Default::default()
        };
        let reqs = vec![req(0, 0.0, 4)]; // service 40 ms > 5
        let err = Scheduler::run(&cfg, &mut svc(), &reqs).unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
    }

    #[test]
    fn batch_members_free_ledger_at_their_own_finish() {
        // Two co-batched sessions of different lengths: the short one's
        // completion releases its ledger bytes (and closed-loop successor)
        // before the long one finishes.
        let cfg = SchedulerConfig {
            max_batch: 2,
            memory: MemoryModel {
                budget_bytes: 10_000,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 0,
            },
            ..Default::default()
        };
        let reqs = vec![req(0, 0.0, 2), req(1, 0.0, 12)];
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0).with_batch_marginal(0.0);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).unwrap();
        let short = out.records.iter().find(|r| r.id == 0).unwrap();
        let long = out.records.iter().find(|r| r.id == 1).unwrap();
        assert!(short.finish_ms < long.finish_ms);
        assert_eq!(short.replica, long.replica);
        assert_eq!(short.start_ms, long.start_ms, "dispatched as one batch");
    }
}
