//! Multi-tenant load-test subsystem: arrival traces, continuous
//! scheduling, and SLO metrics.
//!
//! The paper evaluates one sequence at a time (§4.4); this layer turns the
//! repo into a load-testable inference *service* while keeping every
//! result deterministic by seed, because all of it runs in virtual time:
//!
//! * [`arrivals`] — seeded open-loop workload generators (Poisson, bursty
//!   ON-OFF, replayed traces) and closed-loop clients with think time,
//!   drawing per-request prompt/output lengths from
//!   [`crate::workload::Corpus`].
//! * [`scheduler`] — continuous virtual-time scheduling semantics that
//!   multiplex in-flight sessions across a pool of engine replicas, with
//!   pluggable policies (FCFS / SJF / SLO-aware EDF), admission control
//!   backed by a per-replica KV + expert-weight memory ledger
//!   ([`crate::cluster::Node`]'s byte accounting), preemption of
//!   over-budget sessions at token boundaries, and multi-session batched
//!   dispatch: an idle replica takes up to
//!   [`scheduler::SchedulerConfig::max_batch`] admitted sessions as one
//!   co-scheduled decode batch (see
//!   [`crate::coordinator::BatchEngine`] and DESIGN.md §7).
//! * [`events`] — the heap-based executor behind those semantics
//!   (DESIGN.md §13): one event heap, a struct-of-arrays session arena,
//!   and a streaming-summary mode ([`events::run_streamed`]) that takes
//!   serving runs to a million sessions in bounded memory. The original
//!   round loop survives as the equivalence oracle
//!   ([`scheduler::CoreKind`] selects).
//! * [`metrics`] — streaming latency histograms with exact nearest-rank
//!   p50/p95/p99 TTFT and TPOT, goodput (tokens meeting SLO), and
//!   queue-depth timelines, broken down per tenant; [`BoundedHistogram`]
//!   keeps percentiles meaningful past the point where retaining every
//!   sample stops being.
//! * [`harness`] — sweep drivers that run any [`Engine`] (OD-MoE and
//!   every baseline) across arrival rates, batch sizes and worker-failure
//!   counts, emitting the deterministic `BENCH_serve.json`,
//!   `BENCH_batch.json`, `BENCH_failover.json`, `BENCH_cache.json`,
//!   `BENCH_precision.json`, `BENCH_scale.json` and
//!   `BENCH_autoscale.json` artifacts; independent sweep cells fan out
//!   across [`harness::parallel_map`] workers with index-ordered merges,
//!   so `--threads` changes wall-clock and nothing else. The autoscale
//!   sweep pits the static fleet against the [`crate::control`] loop on
//!   identical arrival streams under traffic drift (DESIGN.md §15).
//!
//! Failures surface at two levels: engine-level node faults
//! ([`crate::coordinator::FailureSpec`], DESIGN.md §8) reroute expert
//! loads inside a replica, and scheduler-level replica fail-stops
//! ([`scheduler::SchedulerConfig::replica_failures`]) re-queue a dead
//! replica's admitted sessions with their ledger bytes released.
//!
//! How virtual time composes with engine clocks: each engine measures one
//! session's service (TTFT + decode) on its own virtual clock, reset per
//! request; the scheduler maps that measured profile onto the global
//! serving timeline at dispatch time. Replicas of the same engine are
//! identical by construction (engines are deterministic after `reset`),
//! so one measuring instance backs any number of replica slots — see
//! [`scheduler::ServiceModel`].
//!
//! [`Engine`]: crate::coordinator::Engine

pub mod arrivals;
pub mod events;
pub mod harness;
pub mod metrics;
pub mod scheduler;

pub use arrivals::{ArrivalModel, LenDist, TenantSpec, WorkloadSpec};
pub use events::{run_streamed, ScaleStats};
pub use harness::{
    attrib_json, attribution_sweep, autoscale_json, autoscale_scenarios, autoscale_sweep,
    batch_sweep, batch_sweep_json, cache_json, cache_sweep, config_from_args, control_report_json,
    failover_json, failover_sweep, overlap_json, overlap_sweep, parallel_map, parse_batches,
    parse_cache_budgets, parse_chunk_counts, parse_depths, parse_fleet_grid, parse_policy_grid,
    parse_rates, parse_replica_failures, parse_scale_sessions, precision_json, precision_sweep,
    rate_sweep, scale_json, scale_sweep, scale_workload, sweep_json, write_bench, AttribPoint,
    AutoscaleCell, AutoscaleScenario, BatchPoint, CachePoint, DemandService, FailoverPoint,
    OverlapPoint, PrecisionCell, PrecisionMeasurement, ScaleCell, SCALE_SAMPLE_CAP,
};
pub use metrics::{
    BoundedHistogram, Histogram, Percentiles, ServeReport, TenantReport, WindowedHistogram,
};
pub use scheduler::{
    BatchEngineService, BatchStats, CoreKind, EngineService, MemoryModel, Policy, Scheduler,
    SchedulerConfig, ServeOutcome, ServiceModel, SessionOutcome, SessionProfile, SessionRecord,
    SyntheticService,
};

use crate::cluster::Ms;

/// Latency service-level objective for one request: TTFT from eligibility
/// and mean time-per-output-token budgets. A request meets its SLO iff it
/// completes with both within budget (the goodput criterion).
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    pub ttft_ms: Ms,
    pub tpot_ms: Ms,
}

impl Slo {
    pub fn new(ttft_ms: Ms, tpot_ms: Ms) -> Self {
        Self { ttft_ms, tpot_ms }
    }

    /// No latency objective: met by any completed request. (The goodput
    /// predicate itself is [`scheduler::SessionRecord::slo_met`].)
    pub fn relaxed() -> Self {
        Self { ttft_ms: f64::INFINITY, tpot_ms: f64::INFINITY }
    }
}

impl Default for Slo {
    fn default() -> Self {
        Self::relaxed()
    }
}

/// One serving request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// SLO class (index into the workload's tenant list).
    pub tenant: usize,
    /// Logical client session. Open-loop generators use a unique client
    /// per request; closed-loop clients issue their requests one at a
    /// time, each `think_ms` after the previous one completes.
    pub client: u64,
    pub prompt: Vec<u32>,
    pub out_tokens: usize,
    /// Earliest arrival in virtual ms (closed-loop requests may become
    /// eligible later, gated by their client's previous completion).
    pub arrival_ms: Ms,
    /// Closed-loop think time before this request, after the client's
    /// previous completion. Zero for open-loop requests.
    pub think_ms: Ms,
    pub slo: Slo,
}

impl Request {
    /// An open-loop request with no SLO (its own client, no think time).
    pub fn open_loop(id: u64, prompt: Vec<u32>, out_tokens: usize, arrival_ms: Ms) -> Self {
        Self {
            id,
            tenant: 0,
            client: id,
            prompt,
            out_tokens,
            arrival_ms,
            think_ms: 0.0,
            slo: Slo::relaxed(),
        }
    }
}
