//! SLO metrics: streaming latency histograms with exact nearest-rank
//! percentiles, goodput accounting, queue-depth timelines, and per-tenant
//! breakdowns — the serving counterpart of the paper's per-prompt
//! [`crate::metrics::SpeedStats`].

use crate::cluster::Ms;
use crate::metrics::percentile_sorted;
use crate::util::json::Json;

use super::scheduler::{ServeOutcome, SessionOutcome};

/// Streaming sample sink with exact percentiles: O(1) append, and a
/// cached sorted snapshot (dirty-flagged) shared by every read, so
/// interleaved `p()` / `summary()` calls sort once per batch of pushes
/// instead of cloning + re-sorting the whole series per call.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sum: f64,
    /// Sorted snapshot of `samples`; stale iff `dirty`.
    sorted: Vec<f64>,
    dirty: bool,
}

impl Histogram {
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        self.sum += v;
        self.dirty = true;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    /// The cached sorted view, rebuilt only after new pushes.
    fn sorted(&mut self) -> &[f64] {
        if self.dirty {
            self.sorted.clear();
            self.sorted.extend_from_slice(&self.samples);
            self.sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.dirty = false;
        }
        &self.sorted
    }

    /// Exact nearest-rank quantile (0 on an empty sample).
    pub fn p(&mut self, q: f64) -> f64 {
        percentile_sorted(self.sorted(), q)
    }

    pub fn summary(&mut self) -> Percentiles {
        let mean = self.mean();
        let sorted = self.sorted();
        Percentiles {
            count: sorted.len(),
            mean,
            p50: percentile_sorted(sorted, 0.50),
            p95: percentile_sorted(sorted, 0.95),
            p99: percentile_sorted(sorted, 0.99),
        }
    }
}

/// Compact percentile summary of one latency series.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", num(self.mean)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
        ])
    }
}

/// One tenant's slice of a serving run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub offered: usize,
    pub completed: usize,
    pub slo_attainment: f64,
    pub goodput_tok_s: f64,
    pub ttft: Percentiles,
}

impl TenantReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("slo_attainment", num(self.slo_attainment)),
            ("goodput_tok_s", num(self.goodput_tok_s)),
            ("ttft_ms", self.ttft.to_json()),
        ])
    }
}

/// Aggregate report for one (system, arrival-rate) serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub system: String,
    pub rate_per_s: f64,
    pub offered: usize,
    pub completed: usize,
    pub preempted: usize,
    pub rejected: usize,
    pub makespan_ms: Ms,
    /// All generated tokens (including preempted sessions' partial
    /// output).
    pub total_tokens: usize,
    /// Tokens of requests that met their SLO.
    pub goodput_tokens: usize,
    pub throughput_req_s: f64,
    pub throughput_tok_s: f64,
    pub goodput_tok_s: f64,
    /// SLO-met fraction over all offered requests.
    pub slo_attainment: f64,
    pub ttft: Percentiles,
    pub tpot: Percentiles,
    pub e2e: Percentiles,
    pub queued: Percentiles,
    /// Time-weighted mean of the queue-depth timeline.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    pub mean_stall_ms: f64,
    pub tenants: Vec<TenantReport>,
}

impl ServeReport {
    pub fn from_outcome(
        system: &str,
        rate_per_s: f64,
        out: &ServeOutcome,
        tenant_names: &[String],
    ) -> Self {
        let mut ttft = Histogram::default();
        let mut tpot = Histogram::default();
        let mut e2e = Histogram::default();
        let mut queued = Histogram::default();
        let (mut completed, mut preempted, mut rejected) = (0usize, 0usize, 0usize);
        let (mut total_tokens, mut goodput_tokens, mut slo_met) = (0usize, 0usize, 0usize);
        let mut stall_sum = 0.0;

        let nt = tenant_names.len().max(1);
        let mut t_ttft: Vec<Histogram> = vec![Histogram::default(); nt];
        let mut t_offered = vec![0usize; nt];
        let mut t_completed = vec![0usize; nt];
        let mut t_met = vec![0usize; nt];
        let mut t_good = vec![0usize; nt];

        for rec in &out.records {
            let t = rec.tenant.min(nt - 1);
            t_offered[t] += 1;
            match rec.outcome {
                SessionOutcome::Completed => completed += 1,
                SessionOutcome::Preempted => preempted += 1,
                SessionOutcome::Rejected => {
                    rejected += 1;
                    continue;
                }
            }
            if let Some(v) = rec.ttft_ms() {
                ttft.push(v);
                t_ttft[t].push(v);
            }
            if let Some(v) = rec.tpot_ms() {
                tpot.push(v);
            }
            e2e.push(rec.e2e_ms());
            queued.push(rec.queued_ms());
            total_tokens += rec.tokens.len();
            stall_sum += rec.stall_ms;
            if rec.outcome == SessionOutcome::Completed {
                t_completed[t] += 1;
            }
            if rec.slo_met() {
                slo_met += 1;
                goodput_tokens += rec.tokens.len();
                t_met[t] += 1;
                t_good[t] += rec.tokens.len();
            }
        }

        let offered = out.records.len();
        let span_s = out.makespan_ms / 1000.0;
        let per_s = |x: f64| if span_s > 0.0 { x / span_s } else { 0.0 };
        let served = completed + preempted;

        let tenants = (0..nt)
            .map(|t| TenantReport {
                name: tenant_names.get(t).cloned().unwrap_or_else(|| format!("tenant{t}")),
                offered: t_offered[t],
                completed: t_completed[t],
                slo_attainment: if t_offered[t] > 0 {
                    t_met[t] as f64 / t_offered[t] as f64
                } else {
                    0.0
                },
                goodput_tok_s: per_s(t_good[t] as f64),
                ttft: t_ttft[t].summary(),
            })
            .collect();

        Self {
            system: system.to_string(),
            rate_per_s,
            offered,
            completed,
            preempted,
            rejected,
            makespan_ms: out.makespan_ms,
            total_tokens,
            goodput_tokens,
            throughput_req_s: per_s(completed as f64),
            throughput_tok_s: per_s(total_tokens as f64),
            goodput_tok_s: per_s(goodput_tokens as f64),
            slo_attainment: if offered > 0 { slo_met as f64 / offered as f64 } else { 0.0 },
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            queued: queued.summary(),
            mean_queue_depth: mean_depth(&out.queue_depth, out.makespan_ms),
            max_queue_depth: out.queue_depth.iter().map(|&(_, d)| d).max().unwrap_or(0),
            mean_stall_ms: if served > 0 { stall_sum / served as f64 } else { 0.0 },
            tenants,
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rate_per_s", num(self.rate_per_s)),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("preempted", Json::Num(self.preempted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("makespan_ms", num(self.makespan_ms)),
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("goodput_tokens", Json::Num(self.goodput_tokens as f64)),
            ("throughput_req_s", num(self.throughput_req_s)),
            ("throughput_tok_s", num(self.throughput_tok_s)),
            ("goodput_tok_s", num(self.goodput_tok_s)),
            ("slo_attainment", num(self.slo_attainment)),
            ("ttft_ms", self.ttft.to_json()),
            ("tpot_ms", self.tpot.to_json()),
            ("e2e_ms", self.e2e.to_json()),
            ("queued_ms", self.queued.to_json()),
            ("mean_queue_depth", num(self.mean_queue_depth)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("mean_stall_ms", num(self.mean_stall_ms)),
            ("tenants", Json::Arr(self.tenants.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

/// Time-weighted mean of a step timeline over `[0, makespan]`.
fn mean_depth(timeline: &[(Ms, usize)], makespan: Ms) -> f64 {
    if makespan <= 0.0 || timeline.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for w in timeline.windows(2) {
        acc += w[0].1 as f64 * (w[1].0 - w[0].0);
    }
    let (t_last, d_last) = *timeline.last().expect("checked non-empty");
    acc += d_last as f64 * (makespan - t_last).max(0.0);
    acc / makespan
}

// The serve layer's JSON builders grew into the shared helpers in
// [`crate::util::json`]; re-exported here so serve modules keep their
// short paths.
pub(crate) use crate::util::json::{num, obj};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::{Scheduler, SchedulerConfig, SyntheticService};
    use crate::serve::{Request, Slo};

    #[test]
    fn histogram_exact_percentiles() {
        let mut h = Histogram::default();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            h.push(v);
        }
        assert_eq!(h.len(), 5);
        assert_eq!(h.p(0.5), 3.0);
        assert_eq!(h.p(0.95), 5.0);
        assert_eq!(h.mean(), 3.0);
        assert_eq!(Histogram::default().p(0.99), 0.0);
    }

    #[test]
    fn cached_percentiles_match_a_fresh_sort() {
        // Pin the cached-sort read path against the clone-and-sort
        // reference, with reads interleaved between pushes so the dirty
        // flag is exercised on every rebuild.
        let mut h = Histogram::default();
        let mut raw: Vec<f64> = Vec::new();
        let mut x = 7u64;
        for i in 0..64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (x >> 33) as f64 / 1e6;
            h.push(v);
            raw.push(v);
            if i % 7 == 0 {
                for q in [0.5, 0.95, 0.99] {
                    assert_eq!(h.p(q), crate::metrics::percentile(&raw, q));
                }
            }
        }
        let s = h.summary();
        assert_eq!(s.count, 64);
        assert_eq!(s.p50, crate::metrics::percentile(&raw, 0.5));
        assert_eq!(s.p95, crate::metrics::percentile(&raw, 0.95));
        assert_eq!(s.p99, crate::metrics::percentile(&raw, 0.99));
    }

    #[test]
    fn mean_depth_is_time_weighted() {
        // depth 2 over [0,10), 0 over [10,20) -> mean 1.
        let tl = vec![(0.0, 2), (10.0, 0)];
        assert!((mean_depth(&tl, 20.0) - 1.0).abs() < 1e-12);
        assert_eq!(mean_depth(&[], 10.0), 0.0);
    }

    #[test]
    fn report_counts_goodput_only_within_slo() {
        // Two requests back to back on one replica; service 40 ms each.
        // SLO TTFT 30 ms: the first (ttft 10) meets it, the queued second
        // (ttft 50) does not.
        let slo = Slo::new(30.0, 20.0);
        let mut reqs: Vec<Request> = (0..2)
            .map(|i| Request::open_loop(i, vec![1, 2], 4, 0.0))
            .collect();
        for r in &mut reqs {
            r.slo = slo;
        }
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0);
        let out = Scheduler::run(&SchedulerConfig::default(), &mut svc, &reqs).unwrap();
        let rep =
            ServeReport::from_outcome("stub", 1.0, &out, &["default".to_string()]);
        assert_eq!(rep.offered, 2);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.goodput_tokens, 4, "only the unqueued request's tokens count");
        assert_eq!(rep.total_tokens, 8);
        assert!((rep.slo_attainment - 0.5).abs() < 1e-12);
        // 8 tokens over 80 ms makespan = 100 tok/s; goodput half of that.
        assert!((rep.throughput_tok_s - 100.0).abs() < 1e-9);
        assert!((rep.goodput_tok_s - 50.0).abs() < 1e-9);
    }
}
