//! SLO metrics: streaming latency histograms with exact nearest-rank
//! percentiles, goodput accounting, queue-depth timelines, and per-tenant
//! breakdowns — the serving counterpart of the paper's per-prompt
//! [`crate::metrics::SpeedStats`].

use crate::cluster::Ms;
use crate::metrics::percentile_sorted;
use crate::util::json::Json;

use super::scheduler::{ServeOutcome, SessionOutcome};

/// Streaming sample sink with exact percentiles: O(1) append, and a
/// cached sorted snapshot (dirty-flagged) shared by every read, so
/// interleaved `p()` / `summary()` calls sort once per batch of pushes
/// instead of cloning + re-sorting the whole series per call.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sum: f64,
    /// Sorted snapshot of `samples`; stale iff `dirty`.
    sorted: Vec<f64>,
    dirty: bool,
}

impl Histogram {
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
        self.sum += v;
        self.dirty = true;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    /// The cached sorted view, rebuilt only after new pushes.
    fn sorted(&mut self) -> &[f64] {
        if self.dirty {
            self.sorted.clear();
            self.sorted.extend_from_slice(&self.samples);
            self.sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.dirty = false;
        }
        &self.sorted
    }

    /// Exact nearest-rank quantile (0 on an empty sample).
    pub fn p(&mut self, q: f64) -> f64 {
        percentile_sorted(self.sorted(), q)
    }

    pub fn summary(&mut self) -> Percentiles {
        let mean = self.mean();
        let sorted = self.sorted();
        Percentiles {
            count: sorted.len(),
            mean,
            p50: percentile_sorted(sorted, 0.50),
            p95: percentile_sorted(sorted, 0.95),
            p99: percentile_sorted(sorted, 0.99),
        }
    }
}

/// Pending pushes accumulated before each compact sorted merge.
const MERGE_BATCH: usize = 1024;
/// Log-spaced bins for the streaming fallback: 8 per octave over
/// [2^-10, 2^54) ms — sub-microsecond to beyond any virtual makespan —
/// so a bin's edges are within 2^(1/8) ≈ 9% of each other.
const BINS_PER_OCTAVE: f64 = 8.0;
const BIN_FLOOR_LOG2: f64 = -10.0;
const N_BINS: usize = 512;

/// Bounded-memory latency sink for million-session runs ([`Histogram`]
/// retains every sample; this one cannot). Up to `sample_cap` samples it
/// keeps the exact series in sorted form — new pushes buffer and fold in
/// via compact sorted merges, so there is never a full re-sort of the
/// whole series — and percentiles are exact, identical to
/// [`Histogram`]'s. Past the cap it degrades *explicitly*: retained
/// samples spill into logarithmic bins, [`BoundedHistogram::is_exact`]
/// flips to false, and percentiles come from a cumulative bin walk
/// (error bounded by the ~9% bin width). Count, sum, min and max stay
/// exact at every scale.
#[derive(Debug, Clone)]
pub struct BoundedHistogram {
    cap: usize,
    /// Retained samples, sorted (exact regime only).
    sorted: Vec<f64>,
    /// Recent pushes not yet merged into `sorted`.
    pending: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Populated only after the cap is crossed.
    bins: Vec<u64>,
    exact: bool,
}

impl BoundedHistogram {
    pub fn new(sample_cap: usize) -> Self {
        Self {
            cap: sample_cap,
            sorted: Vec::new(),
            pending: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            bins: Vec::new(),
            exact: true,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.exact {
            if self.count <= self.cap as u64 {
                self.pending.push(v);
                if self.pending.len() >= MERGE_BATCH {
                    self.merge_pending();
                }
                return;
            }
            // Crossing the cap: spill everything retained into bins and
            // stay there — a run either fits the exact regime or it
            // doesn't.
            self.merge_pending();
            self.exact = false;
            self.bins = vec![0; N_BINS];
            for s in std::mem::take(&mut self.sorted) {
                self.bins[Self::bin(s)] += 1;
            }
        }
        self.bins[Self::bin(v)] += 1;
    }

    /// Whether `summary` percentiles are exact (sample count never
    /// exceeded the cap) or log-bin approximations.
    pub fn is_exact(&self) -> bool {
        self.exact
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Fold `pending` into `sorted`: sort the small batch, then one
    /// linear merge — O(cap) per batch instead of O(cap log cap).
    fn merge_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.pending.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut merged = Vec::with_capacity(self.sorted.len() + self.pending.len());
        let (mut i, mut j) = (0, 0);
        while i < self.sorted.len() && j < self.pending.len() {
            if self.sorted[i] <= self.pending[j] {
                merged.push(self.sorted[i]);
                i += 1;
            } else {
                merged.push(self.pending[j]);
                j += 1;
            }
        }
        merged.extend_from_slice(&self.sorted[i..]);
        merged.extend_from_slice(&self.pending[j..]);
        self.sorted = merged;
        self.pending.clear();
    }

    fn bin(v: f64) -> usize {
        let l = v.max(2f64.powf(BIN_FLOOR_LOG2)).log2();
        (((l - BIN_FLOOR_LOG2) * BINS_PER_OCTAVE) as usize).min(N_BINS - 1)
    }

    /// Geometric midpoint of bin `i` — the representative an approximate
    /// quantile reports.
    fn bin_value(i: usize) -> f64 {
        2f64.powf(BIN_FLOOR_LOG2 + (i as f64 + 0.5) / BINS_PER_OCTAVE)
    }

    /// Nearest-rank quantile over the cumulative bin counts, clamped to
    /// the exact observed [min, max].
    fn approx_quantile(&self, q: f64) -> f64 {
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bin_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn summary(&mut self) -> Percentiles {
        if self.count == 0 {
            return Percentiles::default();
        }
        let mean = self.sum / self.count as f64;
        if self.exact {
            self.merge_pending();
            return Percentiles {
                count: self.count as usize,
                mean,
                p50: percentile_sorted(&self.sorted, 0.50),
                p95: percentile_sorted(&self.sorted, 0.95),
                p99: percentile_sorted(&self.sorted, 0.99),
            };
        }
        Percentiles {
            count: self.count as usize,
            mean,
            p50: self.approx_quantile(0.50),
            p95: self.approx_quantile(0.95),
            p99: self.approx_quantile(0.99),
        }
    }
}

/// Rolling-window percentile sink for the SLO control loop (DESIGN.md
/// §15): a ring buffer over exactly the last `window` samples, with
/// exact nearest-rank percentiles over the current contents. Where
/// [`Histogram`] answers "the whole run so far" and [`BoundedHistogram`]
/// "the whole run, bounded", this answers "the recent past" — the signal
/// an epoch controller reacts to. The window is small (hundreds), so
/// reads sort a copy; pushes are O(1) and allocation-free once full.
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    window: usize,
    buf: Vec<f64>,
    /// Next overwrite position once the buffer is full.
    head: usize,
    pushed: u64,
}

impl WindowedHistogram {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "need a positive window");
        Self { window, buf: Vec::with_capacity(window), head: 0, pushed: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.window {
            self.buf.push(v);
        } else {
            self.buf[self.head] = v;
            self.head = (self.head + 1) % self.window;
        }
        self.pushed += 1;
    }

    /// Samples currently in the window (≤ the configured width).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Lifetime push count (samples seen, not retained).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Configured window width (max retained samples).
    pub fn window(&self) -> usize {
        self.window
    }

    /// Current window contents, oldest sample first — what a merge or a
    /// replay would re-push to reproduce this window.
    pub fn ordered(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.buf.len());
        if self.buf.len() < self.window {
            v.extend_from_slice(&self.buf);
        } else {
            v.extend_from_slice(&self.buf[self.head..]);
            v.extend_from_slice(&self.buf[..self.head]);
        }
        v
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.buf.iter().sum::<f64>() / self.buf.len() as f64
        }
    }

    /// Exact nearest-rank quantile over the current window (0 when
    /// empty) — same convention as [`Histogram::p`], which the unit
    /// tests pin it against on a full window.
    pub fn p(&self, q: f64) -> f64 {
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&s, q)
    }

    pub fn summary(&self) -> Percentiles {
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles {
            count: s.len(),
            mean: self.mean(),
            p50: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
            p99: percentile_sorted(&s, 0.99),
        }
    }

    /// Coarse log-binned view of the current window — (bin midpoint,
    /// count) for every non-empty bin, on exactly the bin edges of
    /// [`BoundedHistogram`]'s streaming fallback, so windowed exports
    /// and whole-run exports bucket identically.
    pub fn log_bins(&self) -> Vec<(f64, u64)> {
        let mut counts = vec![0u64; N_BINS];
        for &v in &self.buf {
            counts[BoundedHistogram::bin(v)] += 1;
        }
        counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (BoundedHistogram::bin_value(i), c))
            .collect()
    }
}

/// Compact percentile summary of one latency series.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", num(self.mean)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
        ])
    }
}

/// One tenant's slice of a serving run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub offered: usize,
    pub completed: usize,
    pub slo_attainment: f64,
    pub goodput_tok_s: f64,
    pub ttft: Percentiles,
}

impl TenantReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("slo_attainment", num(self.slo_attainment)),
            ("goodput_tok_s", num(self.goodput_tok_s)),
            ("ttft_ms", self.ttft.to_json()),
        ])
    }
}

/// Aggregate report for one (system, arrival-rate) serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub system: String,
    pub rate_per_s: f64,
    pub offered: usize,
    pub completed: usize,
    pub preempted: usize,
    pub rejected: usize,
    pub makespan_ms: Ms,
    /// All generated tokens (including preempted sessions' partial
    /// output).
    pub total_tokens: usize,
    /// Tokens of requests that met their SLO.
    pub goodput_tokens: usize,
    pub throughput_req_s: f64,
    pub throughput_tok_s: f64,
    pub goodput_tok_s: f64,
    /// SLO-met fraction over all offered requests.
    pub slo_attainment: f64,
    pub ttft: Percentiles,
    pub tpot: Percentiles,
    pub e2e: Percentiles,
    pub queued: Percentiles,
    /// Time-weighted mean of the queue-depth timeline.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    pub mean_stall_ms: f64,
    pub tenants: Vec<TenantReport>,
}

impl ServeReport {
    pub fn from_outcome(
        system: &str,
        rate_per_s: f64,
        out: &ServeOutcome,
        tenant_names: &[String],
    ) -> Self {
        let mut ttft = Histogram::default();
        let mut tpot = Histogram::default();
        let mut e2e = Histogram::default();
        let mut queued = Histogram::default();
        let (mut completed, mut preempted, mut rejected) = (0usize, 0usize, 0usize);
        let (mut total_tokens, mut goodput_tokens, mut slo_met) = (0usize, 0usize, 0usize);
        let mut stall_sum = 0.0;

        let nt = tenant_names.len().max(1);
        let mut t_ttft: Vec<Histogram> = vec![Histogram::default(); nt];
        let mut t_offered = vec![0usize; nt];
        let mut t_completed = vec![0usize; nt];
        let mut t_met = vec![0usize; nt];
        let mut t_good = vec![0usize; nt];

        for rec in &out.records {
            let t = rec.tenant.min(nt - 1);
            t_offered[t] += 1;
            match rec.outcome {
                SessionOutcome::Completed => completed += 1,
                SessionOutcome::Preempted => preempted += 1,
                SessionOutcome::Rejected => {
                    rejected += 1;
                    continue;
                }
            }
            if let Some(v) = rec.ttft_ms() {
                ttft.push(v);
                t_ttft[t].push(v);
            }
            if let Some(v) = rec.tpot_ms() {
                tpot.push(v);
            }
            e2e.push(rec.e2e_ms());
            queued.push(rec.queued_ms());
            total_tokens += rec.tokens.len();
            stall_sum += rec.stall_ms;
            if rec.outcome == SessionOutcome::Completed {
                t_completed[t] += 1;
            }
            if rec.slo_met() {
                slo_met += 1;
                goodput_tokens += rec.tokens.len();
                t_met[t] += 1;
                t_good[t] += rec.tokens.len();
            }
        }

        let offered = out.records.len();
        let span_s = out.makespan_ms / 1000.0;
        let per_s = |x: f64| if span_s > 0.0 { x / span_s } else { 0.0 };
        let served = completed + preempted;

        let tenants = (0..nt)
            .map(|t| TenantReport {
                name: tenant_names.get(t).cloned().unwrap_or_else(|| format!("tenant{t}")),
                offered: t_offered[t],
                completed: t_completed[t],
                slo_attainment: if t_offered[t] > 0 {
                    t_met[t] as f64 / t_offered[t] as f64
                } else {
                    0.0
                },
                goodput_tok_s: per_s(t_good[t] as f64),
                ttft: t_ttft[t].summary(),
            })
            .collect();

        Self {
            system: system.to_string(),
            rate_per_s,
            offered,
            completed,
            preempted,
            rejected,
            makespan_ms: out.makespan_ms,
            total_tokens,
            goodput_tokens,
            throughput_req_s: per_s(completed as f64),
            throughput_tok_s: per_s(total_tokens as f64),
            goodput_tok_s: per_s(goodput_tokens as f64),
            slo_attainment: if offered > 0 { slo_met as f64 / offered as f64 } else { 0.0 },
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            queued: queued.summary(),
            mean_queue_depth: mean_depth(&out.queue_depth, out.makespan_ms),
            max_queue_depth: out.queue_depth.iter().map(|&(_, d)| d).max().unwrap_or(0),
            mean_stall_ms: if served > 0 { stall_sum / served as f64 } else { 0.0 },
            tenants,
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rate_per_s", num(self.rate_per_s)),
            ("offered", Json::Num(self.offered as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("preempted", Json::Num(self.preempted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("makespan_ms", num(self.makespan_ms)),
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("goodput_tokens", Json::Num(self.goodput_tokens as f64)),
            ("throughput_req_s", num(self.throughput_req_s)),
            ("throughput_tok_s", num(self.throughput_tok_s)),
            ("goodput_tok_s", num(self.goodput_tok_s)),
            ("slo_attainment", num(self.slo_attainment)),
            ("ttft_ms", self.ttft.to_json()),
            ("tpot_ms", self.tpot.to_json()),
            ("e2e_ms", self.e2e.to_json()),
            ("queued_ms", self.queued.to_json()),
            ("mean_queue_depth", num(self.mean_queue_depth)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("mean_stall_ms", num(self.mean_stall_ms)),
            ("tenants", Json::Arr(self.tenants.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

/// Time-weighted mean of a step timeline over `[0, makespan]`.
fn mean_depth(timeline: &[(Ms, usize)], makespan: Ms) -> f64 {
    if makespan <= 0.0 || timeline.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for w in timeline.windows(2) {
        acc += w[0].1 as f64 * (w[1].0 - w[0].0);
    }
    let (t_last, d_last) = *timeline.last().expect("checked non-empty");
    acc += d_last as f64 * (makespan - t_last).max(0.0);
    acc / makespan
}

// The serve layer's JSON builders grew into the shared helpers in
// [`crate::util::json`]; re-exported here so serve modules keep their
// short paths.
pub(crate) use crate::util::json::{num, obj};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::{Scheduler, SchedulerConfig, SyntheticService};
    use crate::serve::{Request, Slo};

    #[test]
    fn histogram_exact_percentiles() {
        let mut h = Histogram::default();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            h.push(v);
        }
        assert_eq!(h.len(), 5);
        assert_eq!(h.p(0.5), 3.0);
        assert_eq!(h.p(0.95), 5.0);
        assert_eq!(h.mean(), 3.0);
        assert_eq!(Histogram::default().p(0.99), 0.0);
    }

    #[test]
    fn cached_percentiles_match_a_fresh_sort() {
        // Pin the cached-sort read path against the clone-and-sort
        // reference, with reads interleaved between pushes so the dirty
        // flag is exercised on every rebuild.
        let mut h = Histogram::default();
        let mut raw: Vec<f64> = Vec::new();
        let mut x = 7u64;
        for i in 0..64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (x >> 33) as f64 / 1e6;
            h.push(v);
            raw.push(v);
            if i % 7 == 0 {
                for q in [0.5, 0.95, 0.99] {
                    assert_eq!(h.p(q), crate::metrics::percentile(&raw, q));
                }
            }
        }
        let s = h.summary();
        assert_eq!(s.count, 64);
        assert_eq!(s.p50, crate::metrics::percentile(&raw, 0.5));
        assert_eq!(s.p95, crate::metrics::percentile(&raw, 0.95));
        assert_eq!(s.p99, crate::metrics::percentile(&raw, 0.99));
    }

    /// Deterministic LCG stream shared by the bounded-histogram tests.
    fn lcg_stream(n: usize) -> Vec<f64> {
        let mut x = 7u64;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                0.05 + (x >> 33) as f64 / 1e7
            })
            .collect()
    }

    #[test]
    fn bounded_histogram_is_exact_below_the_cap() {
        // Below the cap the bounded sink must agree with Histogram
        // exactly, including with summaries interleaved between pushes
        // (each one forces a compact merge of the pending batch).
        let vals = lcg_stream(3000); // > MERGE_BATCH, < cap
        let mut exact = Histogram::default();
        let mut bounded = BoundedHistogram::new(1 << 16);
        for (i, &v) in vals.iter().enumerate() {
            exact.push(v);
            bounded.push(v);
            if i % 997 == 0 {
                let (a, b) = (exact.summary(), bounded.summary());
                assert_eq!((a.p50, a.p95, a.p99), (b.p50, b.p95, b.p99));
            }
        }
        assert!(bounded.is_exact());
        let (a, b) = (exact.summary(), bounded.summary());
        assert_eq!(a.count, b.count);
        assert_eq!((a.mean, a.p50, a.p95, a.p99), (b.mean, b.p50, b.p95, b.p99));
    }

    #[test]
    fn bounded_histogram_degrades_to_log_bins_above_the_cap() {
        let vals = lcg_stream(5000);
        let mut exact = Histogram::default();
        let mut bounded = BoundedHistogram::new(256);
        for &v in &vals {
            exact.push(v);
            bounded.push(v);
        }
        assert!(!bounded.is_exact(), "5000 samples must overflow a cap of 256");
        assert_eq!(bounded.count(), 5000);
        let (a, b) = (exact.summary(), bounded.summary());
        assert_eq!(a.count, b.count);
        assert!((a.mean - b.mean).abs() < 1e-9, "mean stays exact");
        // Approximate quantiles land within one log bin (edges within
        // 2^(1/8) ≈ 9%) of the true value.
        for (t, approx) in [(a.p50, b.p50), (a.p95, b.p95), (a.p99, b.p99)] {
            assert!((approx / t).log2().abs() <= 1.0 / 8.0 + 1e-9, "true {t} vs approx {approx}");
        }
    }

    #[test]
    fn bounded_histogram_clamps_approx_quantiles_to_observed_range() {
        let mut b = BoundedHistogram::new(2);
        for v in [100.0, 101.0, 102.0, 103.0] {
            b.push(v);
        }
        assert!(!b.is_exact());
        let s = b.summary();
        for p in [s.p50, s.p95, s.p99] {
            assert!((100.0..=103.0).contains(&p), "quantile {p} outside observed range");
        }
        assert_eq!(BoundedHistogram::new(8).summary().count, 0, "empty sink summarizes to zero");
    }

    #[test]
    fn windowed_histogram_matches_exact_path_on_a_full_window() {
        // On a window that holds the whole series, the rolling sink must
        // agree with Histogram exactly — same nearest-rank convention.
        let vals = lcg_stream(200);
        let mut exact = Histogram::default();
        let mut windowed = WindowedHistogram::new(256);
        for &v in &vals {
            exact.push(v);
            windowed.push(v);
        }
        assert_eq!(windowed.len(), 200);
        let (a, b) = (exact.summary(), windowed.summary());
        assert_eq!(a.count, b.count);
        assert_eq!((a.mean, a.p50, a.p95, a.p99), (b.mean, b.p50, b.p95, b.p99));
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(windowed.p(q), exact.p(q));
        }
    }

    #[test]
    fn windowed_histogram_retains_exactly_the_last_window() {
        // Push past the window: percentiles must equal the exact path
        // over only the trailing `window` samples.
        let vals = lcg_stream(500);
        let window = 128;
        let mut windowed = WindowedHistogram::new(window);
        for &v in &vals {
            windowed.push(v);
        }
        let mut tail = Histogram::default();
        for &v in &vals[vals.len() - window..] {
            tail.push(v);
        }
        assert_eq!(windowed.len(), window);
        assert_eq!(windowed.pushed(), 500);
        let (a, b) = (tail.summary(), windowed.summary());
        assert_eq!((a.mean, a.p50, a.p95, a.p99), (b.mean, b.p50, b.p95, b.p99));
        assert_eq!(WindowedHistogram::new(4).p(0.99), 0.0, "empty window reads as zero");
    }

    #[test]
    fn windowed_log_bins_cover_the_window_on_shared_edges() {
        let mut w = WindowedHistogram::new(64);
        for &v in &lcg_stream(64) {
            w.push(v);
        }
        let bins = w.log_bins();
        assert_eq!(bins.iter().map(|&(_, c)| c).sum::<u64>(), 64);
        // Bin midpoints are BoundedHistogram's: re-binning a midpoint
        // lands in its own bin.
        for &(mid, _) in &bins {
            assert_eq!(BoundedHistogram::bin_value(BoundedHistogram::bin(mid)), mid);
        }
    }

    #[test]
    fn mean_depth_is_time_weighted() {
        // depth 2 over [0,10), 0 over [10,20) -> mean 1.
        let tl = vec![(0.0, 2), (10.0, 0)];
        assert!((mean_depth(&tl, 20.0) - 1.0).abs() < 1e-12);
        assert_eq!(mean_depth(&[], 10.0), 0.0);
    }

    #[test]
    fn report_counts_goodput_only_within_slo() {
        // Two requests back to back on one replica; service 40 ms each.
        // SLO TTFT 30 ms: the first (ttft 10) meets it, the queued second
        // (ttft 50) does not.
        let slo = Slo::new(30.0, 20.0);
        let mut reqs: Vec<Request> = (0..2)
            .map(|i| Request::open_loop(i, vec![1, 2], 4, 0.0))
            .collect();
        for r in &mut reqs {
            r.slo = slo;
        }
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0);
        let out = Scheduler::run(&SchedulerConfig::default(), &mut svc, &reqs).unwrap();
        let rep =
            ServeReport::from_outcome("stub", 1.0, &out, &["default".to_string()]);
        assert_eq!(rep.offered, 2);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.goodput_tokens, 4, "only the unqueued request's tokens count");
        assert_eq!(rep.total_tokens, 8);
        assert!((rep.slo_attainment - 0.5).abs() < 1e-12);
        // 8 tokens over 80 ms makespan = 100 tok/s; goodput half of that.
        assert!((rep.throughput_tok_s - 100.0).abs() < 1e-9);
        assert!((rep.goodput_tok_s - 50.0).abs() < 1e-9);
    }
}
