//! Rate-sweep driver: run systems across arrival rates and emit
//! `BENCH_serve.json` — "what does OD-MoE's cacheless loading buy you at
//! 0.5–8 req/s?" as one deterministic artifact.
//!
//! Each (system, rate) point regenerates the workload at that rate from
//! the *same* seed — prompts and lengths are identical across points
//! (sharing [`super::EngineService`]'s measurement memo); only the
//! arrival stream changes, through the rate parameter itself. All state
//! is virtual-time, so the same seed yields a byte-identical JSON file.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::arrivals::{ArrivalModel, LenDist, TenantSpec, WorkloadSpec};
use super::metrics::{num, obj, ServeReport};
use super::scheduler::{MemoryModel, Policy, Scheduler, SchedulerConfig, ServiceModel};
use super::Slo;
use crate::cluster::HardwareProfile;
use crate::runtime::PREFILL_SIZES;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Parse a `--rates 0.5,2,8` list (every rate must be finite and > 0).
pub fn parse_rates(s: &str) -> Result<Vec<f64>> {
    let rates: Vec<f64> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| p.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()?;
    ensure!(!rates.is_empty(), "--rates needs at least one rate");
    ensure!(
        rates.iter().all(|r| r.is_finite() && *r > 0.0),
        "arrival rates must be finite and positive, got {rates:?}"
    );
    Ok(rates)
}

/// Build the workload + scheduler configuration from CLI flags — shared
/// by `od-moe serve` and `examples/load_test.rs` so the two cannot
/// drift. Returns (spec, scheduler config, single-run offered rate).
///
/// Flags: `--requests` (24), `--rate` (2; or legacy `--arrival-gap-ms`),
/// `--arrival poisson|bursty|trace|closed`, `--clients`, `--think-ms`,
/// `--input-len` (else bimodal 16/128), `--out-tokens` (16),
/// `--slo-ttft-ms`/`--slo-tpot-ms` (raw virtual ms), `--tenants` (1–2:
/// single class, or interactive + batch), `--policy fcfs|sjf|edf`,
/// `--replicas`, `--mem-gb`, `--preempt-ms`.
pub fn config_from_args(a: &Args, vocab: u32) -> Result<(WorkloadSpec, SchedulerConfig, f64)> {
    // Back-compat: the old FCFS server took `--arrival-gap-ms`.
    let rate = match a.get("arrival-gap-ms") {
        Some(g) => 1000.0 / g.parse::<f64>()?,
        None => a.f64_or("rate", 2.0)?,
    };
    ensure!(rate.is_finite() && rate > 0.0, "--rate must be finite and positive, got {rate}");
    let requests = a.usize_or("requests", a.usize_or("prompts", 24)?)?;
    let out_tokens = a.usize_or("out-tokens", 16)?;
    let model = WorkloadSpec::parse_model(
        a.get_or("arrival", "poisson"),
        rate,
        a.usize_or("clients", 4)?,
        a.f64_or("think-ms", 500.0)?,
    )?;
    let prompt_len = match a.get("input-len") {
        Some(s) => {
            let len: usize = s.parse()?;
            ensure!(
                PREFILL_SIZES.contains(&len),
                "no prefill executable for --input-len {len} (have {PREFILL_SIZES:?})"
            );
            LenDist::Fixed(len)
        }
        None => LenDist::Bimodal { short: 16, long: 128, p_long: 0.5 },
    };
    // SLO budgets are raw 12-layer virtual ms (x32/12 for paper scale).
    let slo = Slo::new(a.f64_or("slo-ttft-ms", 1000.0)?, a.f64_or("slo-tpot-ms", 150.0)?);
    let tenants = match a.usize_or("tenants", 1)? {
        0 | 1 => vec![TenantSpec::new("default", slo)],
        2 => vec![TenantSpec::new("interactive", slo), TenantSpec::batch()],
        n => anyhow::bail!("--tenants supports 1 or 2 SLO classes, got {n}"),
    };
    let spec = WorkloadSpec {
        model,
        n_requests: requests,
        prompt_len,
        out_tokens: LenDist::Fixed(out_tokens),
        tenants,
        vocab,
    };
    let sched = SchedulerConfig {
        policy: Policy::parse(a.get_or("policy", "fcfs"))?,
        n_replicas: a.usize_or("replicas", 1)?,
        memory: MemoryModel::from_profile(&HardwareProfile::rtx3090(), a.f64_or("mem-gb", 24.0)?),
        preempt_budget_ms: a.get("preempt-ms").map(|s| s.parse::<f64>()).transpose()?,
    };
    Ok((spec, sched, rate))
}

/// Run every system at every rate. Systems are (label, service) pairs —
/// wrap a real engine in [`super::EngineService`], or use
/// [`super::SyntheticService`] for runtime-free scheduler studies.
pub fn rate_sweep(
    systems: &mut [(String, &mut dyn ServiceModel)],
    base: &WorkloadSpec,
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Result<Vec<(String, Vec<ServeReport>)>> {
    ensure!(
        !matches!(base.model, ArrivalModel::ClosedLoop { .. }) || rates.len() <= 1,
        "closed-loop workloads are self-clocked: sweeping rates would relabel identical \
         runs — use one rate or an open-loop arrival model"
    );
    let tenant_names: Vec<String> = base.tenants.iter().map(|t| t.name.clone()).collect();
    let mut out = Vec::with_capacity(systems.len());
    for (name, service) in systems.iter_mut() {
        let mut points = Vec::with_capacity(rates.len());
        for &rate in rates {
            let spec = base.with_rate(rate);
            // One seed for every rate: prompts and lengths are identical
            // across points (so EngineService's memo re-measures each
            // distinct request once per sweep) while the arrival streams
            // still differ through the rate parameter itself.
            let reqs = spec.generate(seed);
            let outcome = Scheduler::run(sched, &mut **service, &reqs)?;
            points.push(ServeReport::from_outcome(name, rate, &outcome, &tenant_names));
        }
        out.push((name.clone(), points));
    }
    Ok(out)
}

/// Assemble the `BENCH_serve.json` document.
pub fn sweep_json(
    results: &[(String, Vec<ServeReport>)],
    base: &WorkloadSpec,
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Json {
    let workload = obj(vec![
        ("model", Json::Str(base.model.label().to_string())),
        ("requests", Json::Num(base.n_requests as f64)),
        ("prompt_len", Json::Str(base.prompt_len.label())),
        ("out_tokens", Json::Str(base.out_tokens.label())),
        (
            "tenants",
            Json::Arr(base.tenants.iter().map(|t| Json::Str(t.name.clone())).collect()),
        ),
    ]);
    let systems = Json::Arr(
        results
            .iter()
            .map(|(name, points)| {
                obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("policy", Json::Str(sched.policy.label().to_string())),
        ("replicas", Json::Num(sched.n_replicas as f64)),
        (
            "preempt_budget_ms",
            sched.preempt_budget_ms.map_or(Json::Null, num),
        ),
        ("rates_per_s", Json::Arr(rates.iter().map(|&r| num(r)).collect())),
        ("workload", workload),
        ("systems", systems),
    ])
}

/// Write a JSON document with a trailing newline.
pub fn write_bench(path: &Path, json: &Json) -> Result<()> {
    std::fs::write(path, format!("{json}\n")).with_context(|| format!("writing {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::SyntheticService;

    #[test]
    fn sweep_is_deterministic_and_covers_all_points() {
        let base = WorkloadSpec::poisson(1.0, 12, 256);
        let rates = [0.5, 2.0, 8.0];
        let sched = SchedulerConfig::default();
        let run = |seed| {
            let mut a = SyntheticService::new(20.0, 0.5, 30.0);
            let mut b = SyntheticService::new(10.0, 0.25, 15.0);
            let mut systems: Vec<(String, &mut dyn ServiceModel)> =
                vec![("slow".into(), &mut a), ("fast".into(), &mut b)];
            let results = rate_sweep(&mut systems, &base, &rates, &sched, seed).unwrap();
            sweep_json(&results, &base, &rates, &sched, seed).to_string()
        };
        let x = run(42);
        assert_eq!(x, run(42), "same seed must reproduce the file byte for byte");
        assert_ne!(x, run(43));
        assert!(x.contains("\"bench\":\"serve\""));
        assert!(x.contains("\"name\":\"slow\""));
        assert!(x.contains("\"p99\""));
        assert!(x.contains("\"goodput_tok_s\""));
    }
}
