//! Sweep drivers for the serving layer's deterministic artifacts:
//!
//! * [`rate_sweep`] → `BENCH_serve.json` — "what does OD-MoE's cacheless
//!   loading buy you at 0.5–8 req/s?"
//! * [`batch_sweep`] → `BENCH_batch.json` — "what does batched decode buy
//!   on top?", sweeping batch size x arrival rate against the sequential
//!   (`max_batch = 1`) baseline, with engine-side expert-loads-per-token
//!   tallies showing the amortization directly.
//! * [`failover_sweep`] → `BENCH_failover.json` — decode under 0..=K
//!   fail-stopped workers (DESIGN.md §8).
//! * [`overlap_sweep`] → `BENCH_overlap.json` — ms/token and
//!   fraction-of-fully-cached vs. transfer chunk count and speculative
//!   prefetch depth (DESIGN.md §9), read against the monolithic
//!   (chunks 1, depth 0) baseline.
//! * [`cache_sweep`] → `BENCH_cache.json` — ms/token and
//!   loads-per-token vs. the tiered cache's GPU-hot budget
//!   (DESIGN.md §12), read against the cacheless (budget 0) baseline
//!   and the fully-cached ceiling, locating the crossover between pure
//!   OD-MoE, tiered residency, and a fully-cached deployment.
//! * [`precision_sweep`] → `BENCH_precision.json` — ms/token *and*
//!   fidelity per (runtime precision policy x fleet x arrival rate)
//!   (DESIGN.md §14), read against the static-fp16 baseline cell of the
//!   same fleet and rate — the honest speed-vs-quality frontier for
//!   slack- and importance-aware transfer downgrades.
//! * [`scale_sweep`] → `BENCH_scale.json` — event-core throughput
//!   (events/sec, arena bytes as a peak-RSS proxy) at 1k..1M synthetic
//!   closed-loop sessions, with the round loop as a comparison point at
//!   the sizes it can still reach (DESIGN.md §13). Cells run across
//!   `--threads` scoped workers; results merge by cell index, so the
//!   deterministic section is byte-identical at any thread count.
//! * [`autoscale_sweep`] → `BENCH_autoscale.json` — the SLO control
//!   loop under traffic drift (DESIGN.md §15): {diurnal, flash-crowd,
//!   rolling-failure} scenarios, each served by the static fleet and by
//!   the reactive controller on the *same* arrival stream, with the
//!   controller's costs (replica-ms, replication bytes, quality debt)
//!   reported next to its latency wins and a `tokens_match_static`
//!   honesty bit per reactive cell.
//!
//! Each (system, point) run regenerates the workload at that rate from
//! the *same* seed — prompts and lengths are identical across points
//! (sharing the service models' measurement memos); only the arrival
//! stream and scheduler knobs change. All state is virtual-time, so the
//! same seed yields byte-identical JSON files.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::arrivals::{ArrivalModel, LenDist, TenantSpec, WorkloadSpec};
use super::events::run_streamed;
use super::metrics::{num, obj, Histogram, Percentiles, ServeReport};
use super::scheduler::{
    BatchStats, CoreKind, MemoryModel, Policy, Scheduler, SchedulerConfig, ServeOutcome,
    ServiceModel, SessionOutcome, SessionProfile, SyntheticService,
};
use super::{Request, Slo};
use crate::cluster::HardwareProfile;
use crate::control::{ControlConfig, ControlReport};
use crate::coordinator::PrecisionPolicy;
use crate::runtime::PREFILL_SIZES;
use crate::telemetry::{DecodeAttribution, Phase, NPHASES};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Parse a `--rates 0.5,2,8` list (every rate must be finite and > 0).
pub fn parse_rates(s: &str) -> Result<Vec<f64>> {
    let rates: Vec<f64> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| p.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()?;
    ensure!(!rates.is_empty(), "--rates needs at least one rate");
    ensure!(
        rates.iter().all(|r| r.is_finite() && *r > 0.0),
        "arrival rates must be finite and positive, got {rates:?}"
    );
    Ok(rates)
}

/// Parse a `--fail-replica 0@500,1@900ms` list into
/// [`SchedulerConfig::replica_failures`] entries. The `@<ms>` grammar is
/// shared with the engine's failure specs
/// (`crate::coordinator::odmoe::parse_at_ms`).
pub fn parse_replica_failures(s: &str) -> Result<Vec<(usize, f64)>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let (ri, at) = crate::coordinator::odmoe::parse_at_ms(p.trim())?;
            let ri: usize =
                ri.parse().with_context(|| format!("bad replica index in {p:?}"))?;
            Ok((ri, at))
        })
        .collect()
}

/// Parse a comma-separated usize sweep list, enforcing a minimum value
/// and prepending the sweep's `baseline` point when absent — the one
/// grammar behind `--batches`, `--chunks` and `--depths`, so their
/// validation cannot drift apart.
fn parse_usize_sweep(s: &str, what: &str, min: usize, baseline: usize) -> Result<Vec<usize>> {
    let mut values: Vec<usize> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| p.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad {what} list {s:?}"))?;
    ensure!(!values.is_empty(), "{what} list needs at least one entry");
    ensure!(
        values.iter().all(|&v| v >= min),
        "every {what} must be >= {min}, got {values:?}"
    );
    if !values.contains(&baseline) {
        values.insert(0, baseline);
    }
    Ok(values)
}

/// Parse a `--batches 1,2,4,8` list. Batch 1 — the sequential baseline —
/// is prepended when absent, so every sweep carries its own reference.
pub fn parse_batches(s: &str) -> Result<Vec<usize>> {
    parse_usize_sweep(s, "batch size", 1, 1)
}

/// Parse a `--chunks 1,2,4,8` list for the overlap sweep. Chunk count 1
/// — the monolithic baseline every other point is read against — is
/// prepended when absent.
pub fn parse_chunk_counts(s: &str) -> Result<Vec<usize>> {
    parse_usize_sweep(s, "chunk count", 1, 1)
}

/// Parse a `--depths 0,1,2` prefetch-depth list for the overlap sweep.
/// Depth 0 — strict single-expert residency, the seed behavior — is
/// prepended when absent.
pub fn parse_depths(s: &str) -> Result<Vec<usize>> {
    parse_usize_sweep(s, "prefetch depth", 0, 0)
}

/// Parse a `--cache-grid 0,2,8,64` GPU-hot budget list for the cache
/// sweep. Budget 0 — the cacheless seed engine every other point is
/// pinned against — is prepended when absent.
pub fn parse_cache_budgets(s: &str) -> Result<Vec<usize>> {
    parse_usize_sweep(s, "cache budget", 0, 0)
}

/// Build the workload + scheduler configuration from CLI flags — shared
/// by `od-moe serve` and `examples/load_test.rs` so the two cannot
/// drift. Returns (spec, scheduler config, single-run offered rate).
///
/// Flags: `--requests` (24), `--rate` (2; or legacy `--arrival-gap-ms`),
/// `--arrival poisson|bursty|trace|diurnal|closed`, `--clients`, `--think-ms`,
/// `--input-len` (else bimodal 16/128), `--out-tokens` (16),
/// `--slo-ttft-ms`/`--slo-tpot-ms` (raw virtual ms), `--tenants` (1–2:
/// single class, or interactive + batch), `--policy fcfs|sjf|edf`,
/// `--replicas`, `--mem-gb`, `--preempt-ms`, `--max-batch` (1 =
/// sequential dispatch), `--shared-prompt` (every request decodes the
/// same prompt — the shared-routing workload), `--fail-replica N@MS`
/// (fail-stop replica N at virtual time MS; its sessions re-queue),
/// `--cache-hot N` (per-worker GPU-hot tier budget; its expert payloads
/// are reserved out of the admission budget up front — DESIGN.md §12 —
/// so 0, the default, leaves the cacheless admission schedule intact),
/// `--core event|round-loop` (scheduler executor, DESIGN.md §13; both
/// produce bit-identical outcomes), `--queue-sample N` (queue-depth
/// trace stride in scheduling ticks; 1, the default, is the historical
/// every-tick trace), `--control off|reactive` (the SLO control loop,
/// DESIGN.md §15; off, the default, builds no controller state at all —
/// tokens *and* timings stay byte-identical to a build without the
/// subsystem) with `--control-epoch MS`, `--control-target-p99 MS`, and
/// `--control-max-replicas N` tuning the reactive mode.
pub fn config_from_args(a: &Args, vocab: u32) -> Result<(WorkloadSpec, SchedulerConfig, f64)> {
    // Back-compat: the old FCFS server took `--arrival-gap-ms`.
    let rate = match a.get("arrival-gap-ms") {
        Some(g) => 1000.0 / g.parse::<f64>()?,
        None => a.f64_or("rate", 2.0)?,
    };
    ensure!(rate.is_finite() && rate > 0.0, "--rate must be finite and positive, got {rate}");
    let requests = a.usize_or("requests", a.usize_or("prompts", 24)?)?;
    let out_tokens = a.usize_or("out-tokens", 16)?;
    let model = WorkloadSpec::parse_model(
        a.get_or("arrival", "poisson"),
        rate,
        a.usize_or("clients", 4)?,
        a.f64_or("think-ms", 500.0)?,
    )?;
    let prompt_len = match a.get("input-len") {
        Some(s) => {
            let len: usize = s.parse()?;
            ensure!(
                PREFILL_SIZES.contains(&len),
                "no prefill executable for --input-len {len} (have {PREFILL_SIZES:?})"
            );
            LenDist::Fixed(len)
        }
        None => LenDist::Bimodal { short: 16, long: 128, p_long: 0.5 },
    };
    // SLO budgets are raw 12-layer virtual ms (x32/12 for paper scale).
    let slo = Slo::new(a.f64_or("slo-ttft-ms", 1000.0)?, a.f64_or("slo-tpot-ms", 150.0)?);
    let tenants = match a.usize_or("tenants", 1)? {
        0 | 1 => vec![TenantSpec::new("default", slo)],
        2 => vec![TenantSpec::new("interactive", slo), TenantSpec::batch()],
        n => anyhow::bail!("--tenants supports 1 or 2 SLO classes, got {n}"),
    };
    let spec = WorkloadSpec {
        model,
        n_requests: requests,
        prompt_len,
        out_tokens: LenDist::Fixed(out_tokens),
        tenants,
        vocab,
        shared_prompt: a.has("shared-prompt"),
    };
    let max_batch = a.usize_or("max-batch", 1)?;
    ensure!(max_batch >= 1, "--max-batch must be >= 1, got {max_batch}");
    let profile = HardwareProfile::rtx3090();
    // GPU-hot cache residency holds its bytes across tokens, so the
    // admission budget only sees what the reservation leaves behind.
    let cache_hot = a.usize_or("cache-hot", 0)?;
    let reserved = (cache_hot as f64 * profile.expert_bytes) as u64;
    let sched = SchedulerConfig {
        policy: Policy::parse(a.get_or("policy", "fcfs"))?,
        n_replicas: a.usize_or("replicas", 1)?,
        memory: MemoryModel::from_profile(&profile, a.f64_or("mem-gb", 24.0)?)
            .with_reservation(reserved),
        preempt_budget_ms: a.get("preempt-ms").map(|s| s.parse::<f64>()).transpose()?,
        max_batch,
        replica_failures: match a.get("fail-replica") {
            Some(s) => parse_replica_failures(s)?,
            None => Vec::new(),
        },
        core: CoreKind::parse(a.get_or("core", "event"))?,
        queue_sample_stride: {
            let stride = a.usize_or("queue-sample", 1)?;
            ensure!(stride >= 1, "--queue-sample must be >= 1, got {stride}");
            stride
        },
        control: match ControlConfig::parse(a.get_or("control", "off"))? {
            Some(base) => {
                let c = ControlConfig {
                    epoch_ms: a.f64_or("control-epoch", base.epoch_ms)?,
                    target_p99_ttft_ms: a.f64_or("control-target-p99", base.target_p99_ttft_ms)?,
                    max_replicas: a.usize_or("control-max-replicas", base.max_replicas)?,
                    ..base
                };
                c.validate()?;
                Some(c)
            }
            None => None,
        },
    };
    Ok((spec, sched, rate))
}

/// Run every system at every rate. Systems are (label, service) pairs —
/// wrap a real engine in [`super::EngineService`], or use
/// [`super::SyntheticService`] for runtime-free scheduler studies.
pub fn rate_sweep(
    systems: &mut [(String, &mut dyn ServiceModel)],
    base: &WorkloadSpec,
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Result<Vec<(String, Vec<ServeReport>)>> {
    ensure!(
        !matches!(base.model, ArrivalModel::ClosedLoop { .. }) || rates.len() <= 1,
        "closed-loop workloads are self-clocked: sweeping rates would relabel identical \
         runs — use one rate or an open-loop arrival model"
    );
    let tenant_names: Vec<String> = base.tenants.iter().map(|t| t.name.clone()).collect();
    let mut out = Vec::with_capacity(systems.len());
    for (name, service) in systems.iter_mut() {
        let mut points = Vec::with_capacity(rates.len());
        for &rate in rates {
            let spec = base.with_rate(rate);
            // One seed for every rate: prompts and lengths are identical
            // across points (so EngineService's memo re-measures each
            // distinct request once per sweep) while the arrival streams
            // still differ through the rate parameter itself.
            let reqs = spec.generate(seed);
            let outcome = Scheduler::run(sched, &mut **service, &reqs)?;
            points.push(ServeReport::from_outcome(name, rate, &outcome, &tenant_names));
        }
        out.push((name.clone(), points));
    }
    Ok(out)
}

/// Assemble the `BENCH_serve.json` document.
pub fn sweep_json(
    results: &[(String, Vec<ServeReport>)],
    base: &WorkloadSpec,
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Json {
    let workload = obj(vec![
        ("model", Json::Str(base.model.label().to_string())),
        ("requests", Json::Num(base.n_requests as f64)),
        ("prompt_len", Json::Str(base.prompt_len.label())),
        ("out_tokens", Json::Str(base.out_tokens.label())),
        (
            "tenants",
            Json::Arr(base.tenants.iter().map(|t| Json::Str(t.name.clone())).collect()),
        ),
    ]);
    let systems = Json::Arr(
        results
            .iter()
            .map(|(name, points)| {
                obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("policy", Json::Str(sched.policy.label().to_string())),
        ("replicas", Json::Num(sched.n_replicas as f64)),
        (
            "preempt_budget_ms",
            sched.preempt_budget_ms.map_or(Json::Null, num),
        ),
        ("rates_per_s", Json::Arr(rates.iter().map(|&r| num(r)).collect())),
        ("workload", workload),
        ("systems", systems),
    ])
}

/// One (batch size, arrival rate) point of a [`batch_sweep`].
#[derive(Debug, Clone)]
pub struct BatchPoint {
    pub max_batch: usize,
    pub report: ServeReport,
    /// Engine-side tallies for the point (None for service models that do
    /// not track any, e.g. the synthetic one without an engine).
    pub stats: Option<BatchStats>,
}

impl BatchPoint {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("max_batch", Json::Num(self.max_batch as f64))];
        if let Some(s) = &self.stats {
            pairs.push(("expert_loads", Json::Num(s.expert_loads as f64)));
            pairs.push(("aborted_loads", Json::Num(s.aborted_loads as f64)));
            pairs.push(("failovers", Json::Num(s.failovers as f64)));
            pairs.push(("decode_tokens", Json::Num(s.decode_tokens as f64)));
            pairs.push(("decode_iterations", Json::Num(s.decode_iterations as f64)));
            pairs.push(("loads_per_token", num(s.loads_per_token())));
            pairs.push(("mean_decode_batch", num(s.mean_batch())));
        }
        pairs.push(("serve", self.report.to_json()));
        obj(pairs)
    }
}

/// Run every system at every batch size x arrival rate, `max_batch = 1`
/// being the sequential baseline every other point is read against.
/// Stats are drained from each service per point, so a point's
/// `loads_per_token` covers exactly the batches it dispatched.
pub fn batch_sweep(
    systems: &mut [(String, &mut dyn ServiceModel)],
    base: &WorkloadSpec,
    batches: &[usize],
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Result<Vec<(String, Vec<BatchPoint>)>> {
    ensure!(!batches.is_empty(), "need at least one batch size");
    ensure!(
        !matches!(base.model, ArrivalModel::ClosedLoop { .. }) || rates.len() <= 1,
        "closed-loop workloads are self-clocked: sweeping rates would relabel identical \
         runs — use one rate or an open-loop arrival model"
    );
    let tenant_names: Vec<String> = base.tenants.iter().map(|t| t.name.clone()).collect();
    let mut out = Vec::with_capacity(systems.len());
    for (name, service) in systems.iter_mut() {
        let mut points = Vec::with_capacity(batches.len() * rates.len());
        for &max_batch in batches {
            let sched = SchedulerConfig { max_batch, ..sched.clone() };
            for &rate in rates {
                let spec = base.with_rate(rate);
                let reqs = spec.generate(seed);
                let _ = service.take_stats(); // drop tallies from prior points
                let outcome = Scheduler::run(&sched, &mut **service, &reqs)?;
                let report = ServeReport::from_outcome(name, rate, &outcome, &tenant_names);
                points.push(BatchPoint { max_batch, report, stats: service.take_stats() });
            }
        }
        out.push((name.clone(), points));
    }
    Ok(out)
}

/// Assemble the `BENCH_batch.json` document.
pub fn batch_sweep_json(
    results: &[(String, Vec<BatchPoint>)],
    base: &WorkloadSpec,
    batches: &[usize],
    rates: &[f64],
    sched: &SchedulerConfig,
    seed: u64,
) -> Json {
    let workload = obj(vec![
        ("model", Json::Str(base.model.label().to_string())),
        ("requests", Json::Num(base.n_requests as f64)),
        ("prompt_len", Json::Str(base.prompt_len.label())),
        ("out_tokens", Json::Str(base.out_tokens.label())),
        (
            "tenants",
            Json::Arr(base.tenants.iter().map(|t| Json::Str(t.name.clone())).collect()),
        ),
    ]);
    let systems = Json::Arr(
        results
            .iter()
            .map(|(name, points)| {
                obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("bench", Json::Str("batch".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("policy", Json::Str(sched.policy.label().to_string())),
        ("replicas", Json::Num(sched.n_replicas as f64)),
        ("batches", Json::Arr(batches.iter().map(|&b| Json::Num(b as f64)).collect())),
        ("rates_per_s", Json::Arr(rates.iter().map(|&r| num(r)).collect())),
        ("workload", workload),
        ("systems", systems),
    ])
}

/// One point of a [`failover_sweep`]: decode under `failed_workers`
/// fail-stopped workers, read against the healthy (0-failure) baseline.
#[derive(Debug, Clone)]
pub struct FailoverPoint {
    pub failed_workers: usize,
    pub decode_ms: f64,
    /// `decode_ms / healthy decode_ms` (1.0 at zero failures).
    pub slowdown: f64,
    pub stall_ms: f64,
    pub loads_per_token: f64,
    /// Loads/computes re-booked after a mid-flight node death.
    pub failovers: u64,
    /// The fault-tolerance contract: the served stream never changes.
    pub tokens_match_healthy: bool,
}

impl FailoverPoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("failed_workers", Json::Num(self.failed_workers as f64)),
            ("decode_ms", num(self.decode_ms)),
            ("slowdown", num(self.slowdown)),
            ("stall_ms", num(self.stall_ms)),
            ("loads_per_token", num(self.loads_per_token)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("tokens_match_healthy", Json::Bool(self.tokens_match_healthy)),
        ])
    }
}

/// Run one decode session at every failure count `0..=max_failed` and
/// report slowdown against the healthy baseline. `run(k)` must execute
/// the *same* session on a fresh engine with `k` workers fail-stopped
/// (the CLI kills workers `0..k`; see `od-moe serve --failover-sweep`).
/// The closure boundary keeps the sweep engine-agnostic and unit-testable
/// without the PJRT runtime.
pub fn failover_sweep<F>(max_failed: usize, mut run: F) -> Result<Vec<FailoverPoint>>
where
    F: FnMut(usize) -> Result<crate::coordinator::BatchRunResult>,
{
    let healthy = run(0)?;
    ensure!(
        healthy.sessions.len() == 1,
        "failover sweep measures one session per run, got {}",
        healthy.sessions.len()
    );
    let base = healthy.sessions[0].decode_ms;
    ensure!(base.is_finite() && base > 0.0, "healthy decode span must be finite and positive");
    let mut points = Vec::with_capacity(max_failed + 1);
    for k in 0..=max_failed {
        let res = if k == 0 { healthy.clone() } else { run(k)? };
        ensure!(res.sessions.len() == 1, "one session per failover run");
        let s = &res.sessions[0];
        ensure!(
            s.decode_ms.is_finite() && s.stall_ms.is_finite(),
            "non-finite decode under {k} failed worker(s) — the failure model regressed"
        );
        points.push(FailoverPoint {
            failed_workers: k,
            decode_ms: s.decode_ms,
            slowdown: s.decode_ms / base,
            stall_ms: s.stall_ms,
            loads_per_token: res.loads_per_token(),
            failovers: res.failovers,
            tokens_match_healthy: s.tokens == healthy.sessions[0].tokens,
        });
    }
    Ok(points)
}

/// Assemble the `BENCH_failover.json` document.
pub fn failover_json(
    points: &[FailoverPoint],
    seed: u64,
    n_workers: usize,
    group_size: usize,
    fail_at_ms: f64,
    out_tokens: usize,
) -> Json {
    obj(vec![
        ("bench", Json::Str("failover".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("n_workers", Json::Num(n_workers as f64)),
        ("group_size", Json::Num(group_size as f64)),
        ("fail_at_ms", num(fail_at_ms)),
        ("out_tokens", Json::Num(out_tokens as f64)),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
    ])
}

/// One point of an [`overlap_sweep`]: decode with expert transfers
/// streamed as `chunks` chunks at speculative staging depth
/// `prefetch_depth`, read against the monolithic (1, 0) baseline and the
/// fully-cached ceiling (DESIGN.md §9).
#[derive(Debug, Clone)]
pub struct OverlapPoint {
    pub chunks: usize,
    pub prefetch_depth: usize,
    pub decode_ms: f64,
    /// Decode virtual time per generated token.
    pub ms_per_token: f64,
    /// `fully-cached ms/token / this point's ms/token` — the paper's
    /// headline "fraction of fully-cached decode speed" (≈ 0.75 for the
    /// monolithic baseline on the default profile; chunking closes the
    /// gap).
    pub frac_of_fully_cached: f64,
    pub stall_ms: f64,
    /// Prediction-driven streams aborted at the gate result.
    pub aborted_loads: u64,
    /// The overlap contract: chunking changes *when* bytes move, never
    /// *which* tokens decode.
    pub tokens_match_baseline: bool,
}

impl OverlapPoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("chunks", Json::Num(self.chunks as f64)),
            ("prefetch_depth", Json::Num(self.prefetch_depth as f64)),
            ("decode_ms", num(self.decode_ms)),
            ("ms_per_token", num(self.ms_per_token)),
            ("frac_of_fully_cached", num(self.frac_of_fully_cached)),
            ("stall_ms", num(self.stall_ms)),
            ("aborted_loads", Json::Num(self.aborted_loads as f64)),
            ("tokens_match_baseline", Json::Bool(self.tokens_match_baseline)),
        ])
    }
}

/// Run one decode session at every (prefetch depth x chunk count) and
/// report ms/token against the fully-cached ceiling. `run(chunks, depth)`
/// must execute the *same* session on a fresh engine configured with
/// that chunk count and staging depth; `(1, 0)` — which both parse
/// helpers guarantee is present — is the monolithic baseline, booked
/// bit-identically to the pre-chunking engine, and every other point's
/// token stream is checked against it. `fully_cached_ms_per_token` is
/// the ceiling from the fully-cached reference engine on the same
/// session. The closure boundary keeps the sweep engine-agnostic and
/// unit-testable without the PJRT runtime.
pub fn overlap_sweep<F>(
    chunk_counts: &[usize],
    depths: &[usize],
    fully_cached_ms_per_token: f64,
    mut run: F,
) -> Result<Vec<OverlapPoint>>
where
    F: FnMut(usize, usize) -> Result<crate::coordinator::BatchRunResult>,
{
    ensure!(
        chunk_counts.contains(&1) && depths.contains(&0),
        "the sweep needs the monolithic (chunks 1, depth 0) baseline point"
    );
    ensure!(
        fully_cached_ms_per_token.is_finite() && fully_cached_ms_per_token > 0.0,
        "fully-cached reference ms/token must be finite and positive"
    );
    let baseline = run(1, 0)?;
    ensure!(
        baseline.sessions.len() == 1,
        "overlap sweep measures one session per run, got {}",
        baseline.sessions.len()
    );
    ensure!(
        baseline.decode_tokens > 0 && baseline.sessions[0].decode_ms > 0.0,
        "baseline decode must produce tokens in positive time"
    );
    let mut points = Vec::with_capacity(chunk_counts.len() * depths.len());
    for &depth in depths {
        for &chunks in chunk_counts {
            let res = if (chunks, depth) == (1, 0) {
                baseline.clone()
            } else {
                run(chunks, depth)?
            };
            ensure!(res.sessions.len() == 1, "one session per overlap run");
            let s = &res.sessions[0];
            ensure!(
                s.decode_ms.is_finite() && s.stall_ms.is_finite() && res.decode_tokens > 0,
                "non-finite decode at chunks {chunks}, depth {depth}"
            );
            let ms_per_token = s.decode_ms / res.decode_tokens as f64;
            points.push(OverlapPoint {
                chunks,
                prefetch_depth: depth,
                decode_ms: s.decode_ms,
                ms_per_token,
                frac_of_fully_cached: fully_cached_ms_per_token / ms_per_token,
                stall_ms: s.stall_ms,
                aborted_loads: res.aborted_loads,
                tokens_match_baseline: s.tokens == baseline.sessions[0].tokens,
            });
        }
    }
    Ok(points)
}

/// Assemble the `BENCH_overlap.json` document.
pub fn overlap_json(
    points: &[OverlapPoint],
    seed: u64,
    chunk_counts: &[usize],
    depths: &[usize],
    out_tokens: usize,
    fully_cached_ms_per_token: f64,
) -> Json {
    obj(vec![
        ("bench", Json::Str("overlap".to_string())),
        ("seed", Json::Num(seed as f64)),
        (
            "chunk_counts",
            Json::Arr(chunk_counts.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        (
            "prefetch_depths",
            Json::Arr(depths.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        ("out_tokens", Json::Num(out_tokens as f64)),
        ("fully_cached_ms_per_token", num(fully_cached_ms_per_token)),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
    ])
}

/// One point of a [`cache_sweep`]: decode with a per-worker GPU-hot
/// tier of `budget` expert slots (0 = the cacheless seed engine), read
/// against the budget-0 baseline and the fully-cached ceiling
/// (DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct CachePoint {
    pub budget: usize,
    pub decode_ms: f64,
    /// Decode virtual time per generated token.
    pub ms_per_token: f64,
    /// Expert-train loads actually streamed per token — GPU-hot hits
    /// skip the train, so this is the axis where the cache's bandwidth
    /// savings show up (1 load/token/slot cacheless, → 0 fully cached).
    pub loads_per_token: f64,
    pub stall_ms: f64,
    /// `fully-cached ms/token / this point's ms/token` — approaches 1
    /// as the hot tier absorbs the working set.
    pub frac_of_fully_cached: f64,
    /// The residency contract: cache budgets change *when and whether*
    /// bytes move, never *which* tokens decode.
    pub tokens_match_baseline: bool,
}

impl CachePoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("budget", Json::Num(self.budget as f64)),
            ("decode_ms", num(self.decode_ms)),
            ("ms_per_token", num(self.ms_per_token)),
            ("loads_per_token", num(self.loads_per_token)),
            ("stall_ms", num(self.stall_ms)),
            ("frac_of_fully_cached", num(self.frac_of_fully_cached)),
            ("tokens_match_baseline", Json::Bool(self.tokens_match_baseline)),
        ])
    }
}

/// Run one decode session at every GPU-hot budget and report ms/token
/// and loads/token against the cacheless baseline and the fully-cached
/// ceiling. `run(budget)` must execute the *same* session on a fresh
/// engine whose tiered cache holds `budget` hot slots per worker;
/// budget 0 — which [`parse_cache_budgets`] guarantees is present — is
/// the cacheless seed engine, booked bit-identically (tokens *and*
/// timings) to a build without the cache subsystem, and every other
/// point's token stream is checked against it.
/// `fully_cached_ms_per_token` is the ceiling from the fully-cached
/// reference engine on the same session. The closure boundary keeps the
/// sweep engine-agnostic and unit-testable without the PJRT runtime.
pub fn cache_sweep<F>(
    budgets: &[usize],
    fully_cached_ms_per_token: f64,
    mut run: F,
) -> Result<Vec<CachePoint>>
where
    F: FnMut(usize) -> Result<crate::coordinator::BatchRunResult>,
{
    ensure!(
        budgets.contains(&0),
        "the sweep needs the cacheless (budget 0) baseline point"
    );
    ensure!(
        fully_cached_ms_per_token.is_finite() && fully_cached_ms_per_token > 0.0,
        "fully-cached reference ms/token must be finite and positive"
    );
    let baseline = run(0)?;
    ensure!(
        baseline.sessions.len() == 1,
        "cache sweep measures one session per run, got {}",
        baseline.sessions.len()
    );
    ensure!(
        baseline.decode_tokens > 0 && baseline.sessions[0].decode_ms > 0.0,
        "baseline decode must produce tokens in positive time"
    );
    let mut points = Vec::with_capacity(budgets.len());
    for &budget in budgets {
        let res = if budget == 0 { baseline.clone() } else { run(budget)? };
        ensure!(res.sessions.len() == 1, "one session per cache run");
        let s = &res.sessions[0];
        ensure!(
            s.decode_ms.is_finite() && s.stall_ms.is_finite() && res.decode_tokens > 0,
            "non-finite decode at cache budget {budget}"
        );
        let ms_per_token = s.decode_ms / res.decode_tokens as f64;
        points.push(CachePoint {
            budget,
            decode_ms: s.decode_ms,
            ms_per_token,
            loads_per_token: res.loads_per_token(),
            stall_ms: s.stall_ms,
            frac_of_fully_cached: fully_cached_ms_per_token / ms_per_token,
            tokens_match_baseline: s.tokens == baseline.sessions[0].tokens,
        });
    }
    Ok(points)
}

/// Assemble the `BENCH_cache.json` document.
pub fn cache_json(
    points: &[CachePoint],
    seed: u64,
    budgets: &[usize],
    fleet: &str,
    policy: &str,
    out_tokens: usize,
    fully_cached_ms_per_token: f64,
) -> Json {
    obj(vec![
        ("bench", Json::Str("cache".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("fleet", Json::Str(fleet.to_string())),
        ("policy", Json::Str(policy.to_string())),
        (
            "cache_budgets",
            Json::Arr(budgets.iter().map(|&b| Json::Num(b as f64)).collect()),
        ),
        ("out_tokens", Json::Num(out_tokens as f64)),
        ("fully_cached_ms_per_token", num(fully_cached_ms_per_token)),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
    ])
}

/// Parse a `--precision-grid static,slack,slack-importance` policy list.
/// The static baseline — the cell every other policy's speedup and token
/// stream are read against — is prepended when absent.
pub fn parse_policy_grid(s: &str) -> Result<Vec<PrecisionPolicy>> {
    let mut policies: Vec<PrecisionPolicy> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| PrecisionPolicy::parse(p.trim()))
        .collect::<Result<_>>()?;
    ensure!(!policies.is_empty(), "--precision-grid needs at least one policy");
    if !policies.contains(&PrecisionPolicy::Static) {
        policies.insert(0, PrecisionPolicy::Static);
    }
    Ok(policies)
}

/// Parse a `--precision-fleets "uniform|jetson:4,nano:2"` list —
/// pipe-separated because fleet specs themselves contain commas. Fleet
/// grammar is validated by the CLI when it builds each engine.
pub fn parse_fleet_grid(s: &str) -> Result<Vec<String>> {
    let fleets: Vec<String> =
        s.split('|').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect();
    ensure!(!fleets.is_empty(), "--precision-fleets needs at least one fleet");
    Ok(fleets)
}

/// What the CLI measured for one (fleet, policy, rate) precision cell:
/// virtual decode timing, the engine's per-tier stream tallies, and
/// fidelity against a fixed reference decode of the same prompts
/// (`workload::fidelity`). The closure boundary keeps the sweep
/// engine-agnostic and unit-testable without the PJRT runtime.
#[derive(Debug, Clone)]
pub struct PrecisionMeasurement {
    pub decode_ms: f64,
    pub decode_tokens: u64,
    /// Expert streams issued at each transfer tier, `[fp16, int8, nf4]`
    /// order (`engine.loads_*` counters; failover suffixes included).
    pub loads: [u64; 3],
    pub skipped_experts: u64,
    pub upgrade_reloads: u64,
    /// Gate-weighted modeled quantization error per routed gate weight
    /// (`engine.quality_debt_frac`, DESIGN.md §14).
    pub quality_debt_frac: f64,
    /// Fidelity vs. the reference decode on the same prompts.
    pub token_match_rate: f64,
    pub mean_kl: f64,
    /// First session's token stream, for the static pinning check.
    pub tokens: Vec<u32>,
}

/// One (fleet, policy, rate) cell of a [`precision_sweep`].
#[derive(Debug, Clone)]
pub struct PrecisionCell {
    pub fleet: String,
    pub policy: PrecisionPolicy,
    pub rate: f64,
    pub meas: PrecisionMeasurement,
    pub ms_per_token: f64,
    /// `static ms/token / this cell's ms/token` at the same fleet and
    /// rate (1.0 for the static cell itself; > 1 when downgrades win).
    pub speedup_vs_static: f64,
    /// The transfer-only contract: policies that never skip an expert
    /// change *how* bytes move, never *which* tokens decode.
    pub tokens_match_static: bool,
}

impl PrecisionCell {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("fleet", Json::Str(self.fleet.clone())),
            ("policy", Json::Str(self.policy.label().to_string())),
            ("rate_per_s", num(self.rate)),
            ("decode_ms", num(self.meas.decode_ms)),
            ("ms_per_token", num(self.ms_per_token)),
            ("speedup_vs_static", num(self.speedup_vs_static)),
            ("loads_fp16", Json::Num(self.meas.loads[0] as f64)),
            ("loads_int8", Json::Num(self.meas.loads[1] as f64)),
            ("loads_nf4", Json::Num(self.meas.loads[2] as f64)),
            ("skipped_experts", Json::Num(self.meas.skipped_experts as f64)),
            ("upgrade_reloads", Json::Num(self.meas.upgrade_reloads as f64)),
            ("quality_debt_frac", num(self.meas.quality_debt_frac)),
            ("token_match_rate", num(self.meas.token_match_rate)),
            ("mean_kl", num(self.meas.mean_kl)),
            ("tokens_match_static", Json::Bool(self.tokens_match_static)),
        ])
    }
}

/// Run every policy at every (fleet, rate) and report speed *and*
/// fidelity against the static baseline cell of the same fleet and rate.
/// `run(fleet, policy, rate)` must decode the *same* workload on a fresh
/// engine configured with that fleet and runtime policy;
/// [`PrecisionPolicy::Static`] — which [`parse_policy_grid`] guarantees
/// is present — is the deployed-precision seed engine, booked
/// bit-identically (tokens *and* timings) to a build without the
/// precision controller, and every other cell's speedup and token stream
/// are read against it.
pub fn precision_sweep<F>(
    fleets: &[String],
    policies: &[PrecisionPolicy],
    rates: &[f64],
    mut run: F,
) -> Result<Vec<PrecisionCell>>
where
    F: FnMut(&str, PrecisionPolicy, f64) -> Result<PrecisionMeasurement>,
{
    ensure!(!fleets.is_empty(), "precision sweep needs at least one fleet");
    ensure!(!rates.is_empty(), "precision sweep needs at least one rate");
    ensure!(
        policies.contains(&PrecisionPolicy::Static),
        "the sweep needs the static baseline policy"
    );
    let mut cells = Vec::with_capacity(fleets.len() * rates.len() * policies.len());
    for fleet in fleets {
        for &rate in rates {
            let base = run(fleet, PrecisionPolicy::Static, rate)?;
            ensure!(
                base.decode_ms.is_finite() && base.decode_tokens > 0 && base.decode_ms > 0.0,
                "static baseline on {fleet} must produce tokens in positive time"
            );
            let base_mpt = base.decode_ms / base.decode_tokens as f64;
            for &policy in policies {
                let meas = if policy == PrecisionPolicy::Static {
                    base.clone()
                } else {
                    run(fleet, policy, rate)?
                };
                ensure!(
                    meas.decode_ms.is_finite() && meas.decode_tokens > 0,
                    "non-finite decode for policy {} on {fleet}",
                    policy.label()
                );
                let ms_per_token = meas.decode_ms / meas.decode_tokens as f64;
                cells.push(PrecisionCell {
                    fleet: fleet.clone(),
                    policy,
                    rate,
                    ms_per_token,
                    speedup_vs_static: base_mpt / ms_per_token,
                    tokens_match_static: meas.tokens == base.tokens,
                    meas,
                });
            }
        }
    }
    Ok(cells)
}

/// Assemble the `BENCH_precision.json` document — the speed-vs-quality
/// frontier for runtime mixed-precision loading (DESIGN.md §14).
pub fn precision_json(
    cells: &[PrecisionCell],
    seed: u64,
    fleets: &[String],
    policies: &[PrecisionPolicy],
    rates: &[f64],
    out_tokens: usize,
) -> Json {
    obj(vec![
        ("bench", Json::Str("precision".to_string())),
        ("schema", Json::Str("odmoe.precision.v1".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("fleets", Json::Arr(fleets.iter().map(|f| Json::Str(f.clone())).collect())),
        (
            "policies",
            Json::Arr(policies.iter().map(|p| Json::Str(p.label().to_string())).collect()),
        ),
        ("rates_per_s", Json::Arr(rates.iter().map(|&r| num(r)).collect())),
        ("out_tokens", Json::Num(out_tokens as f64)),
        ("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect())),
    ])
}

/// One arrival rate's aggregate critical-path attribution in an
/// [`attribution_sweep`]: per-phase time summed over every decoded token
/// of every session served at that rate (DESIGN.md §11).
#[derive(Debug, Clone)]
pub struct AttribPoint {
    pub rate: f64,
    pub sessions: usize,
    pub tokens: usize,
    /// Summed per-phase token time, [`Phase::ALL`] order.
    pub phase_ms: [f64; NPHASES],
}

impl AttribPoint {
    /// Total attributed token time at this point.
    pub fn total_ms(&self) -> f64 {
        self.phase_ms.iter().sum()
    }

    /// The phase binding the largest share of token time.
    pub fn bound(&self) -> Phase {
        let mut best = Phase::Idle;
        let mut best_ms = f64::NEG_INFINITY;
        for p in Phase::ALL {
            if self.phase_ms[p.idx()] > best_ms {
                best = p;
                best_ms = self.phase_ms[p.idx()];
            }
        }
        best
    }

    pub fn to_json(&self) -> Json {
        let total = self.total_ms();
        let phases =
            obj(Phase::ALL.iter().map(|p| (p.name(), num(self.phase_ms[p.idx()]))).collect());
        let fracs = obj(Phase::ALL
            .iter()
            .map(|p| {
                let f = if total > 0.0 { self.phase_ms[p.idx()] / total } else { 0.0 };
                (p.name(), num(f))
            })
            .collect());
        obj(vec![
            ("rate_per_s", num(self.rate)),
            ("sessions", Json::Num(self.sessions as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("total_ms", num(total)),
            ("phases_ms", phases),
            ("phase_frac", fracs),
            ("bound", Json::Str(self.bound().name().into())),
        ])
    }
}

/// Aggregate per-token attribution across sessions at every rate.
/// `run(rate)` must decode the rate's whole workload on a trace-enabled
/// engine and return (sessions served, the decode's attribution) — see
/// `od-moe serve --attribution`. The closure boundary keeps the sweep
/// engine-agnostic and unit-testable without the PJRT runtime.
pub fn attribution_sweep<F>(rates: &[f64], mut run: F) -> Result<Vec<AttribPoint>>
where
    F: FnMut(f64) -> Result<(usize, DecodeAttribution)>,
{
    ensure!(!rates.is_empty(), "attribution sweep needs at least one rate");
    let mut points = Vec::with_capacity(rates.len());
    for &rate in rates {
        let (sessions, attrib) = run(rate)?;
        points.push(AttribPoint {
            rate,
            sessions,
            tokens: attrib.tokens.len(),
            phase_ms: attrib.phase_totals(),
        });
    }
    Ok(points)
}

/// Assemble the `BENCH_attrib.json` document: the fraction of token time
/// bound by each resource, per rate, for one fleet.
pub fn attrib_json(points: &[AttribPoint], seed: u64, fleet: &str) -> Json {
    obj(vec![
        ("bench", Json::Str("attrib".to_string())),
        ("schema", Json::Str("odmoe.attrib.v1".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("fleet", Json::Str(fleet.to_string())),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
    ])
}

/// Run `f` over `items` on up to `threads` scoped workers, returning
/// results in item order regardless of which worker computed what or
/// when. Workers claim indices from a shared counter (no work stealing,
/// no channels) and write into per-index slots, so the only
/// thread-sensitive quantity is wall-clock: anything deterministic per
/// item stays deterministic at every thread count. `threads == 1` runs
/// inline on the caller's stack.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        items.iter().map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().expect("unpoisoned result slot") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("unpoisoned result slot").expect("every item computed"))
        .collect()
}

/// Exact-percentile retention cap for streamed scale cells: runs at or
/// under this many completions keep the full latency series (exact
/// percentiles); larger runs fall back to the bounded histogram's
/// log-binned summaries, flagged `exact_percentiles: false` in the JSON.
pub const SCALE_SAMPLE_CAP: usize = 65_536;

/// Synthetic closed-loop workload for the scale sweep, built directly
/// (bypassing [`WorkloadSpec::generate`]'s per-request machinery, which
/// is fine at thousands of requests and wasteful at a million): one
/// chain per client, `sessions / clients`-deep, single-token prompts,
/// 8 output tokens, exponential think times (mean 10 virtual ms) from
/// the seeded generator. Deterministic per (sessions, clients, seed).
pub fn scale_workload(sessions: usize, clients: usize, seed: u64) -> Vec<Request> {
    let mut rng = crate::model::rng::Rng::new(seed ^ 0x5CA1E);
    (0..sessions)
        .map(|i| {
            let mut r = Request::open_loop(i as u64, vec![1 + (i % 250) as u32], 8, 0.0);
            r.client = (i % clients.max(1)) as u64;
            r.think_ms = -(1.0 - rng.uniform()).ln() * 10.0;
            r
        })
        .collect()
}

/// One measured (session count, core) cell of the scale sweep.
#[derive(Debug, Clone)]
pub struct ScaleCell {
    pub sessions: usize,
    pub core: CoreKind,
    pub completed: u64,
    pub preempted: u64,
    pub rejected: u64,
    pub requeued: usize,
    pub total_tokens: u64,
    pub makespan_ms: f64,
    /// Heap pops over the run (event core only).
    pub events: Option<u64>,
    /// Scheduling ticks (event core only).
    pub ticks: Option<u64>,
    /// Session-arena footprint, the peak-RSS proxy (event core only).
    pub arena_bytes: Option<u64>,
    pub e2e: Percentiles,
    pub exact_percentiles: bool,
    /// Wall-clock for the cell — reported under the separate `"wall"`
    /// keys and never part of the deterministic section.
    pub wall_ms: f64,
}

/// The scale sweep's scheduler shape: 4 replicas x batch 4, unlimited
/// memory (the queue pressure comes from chain gating, not admission),
/// queue-depth stride 64 so the trace stays bounded at a million ticks.
fn scale_config(core: CoreKind) -> SchedulerConfig {
    SchedulerConfig {
        n_replicas: 4,
        max_batch: 4,
        queue_sample_stride: 64,
        core,
        ..SchedulerConfig::default()
    }
}

fn run_scale_cell(sessions: usize, core: CoreKind, seed: u64) -> Result<ScaleCell> {
    // Chains 4 deep: a quarter of the sessions are eligible at once, so
    // the admitted index the dispatcher searches grows with the session
    // count — exactly the regime where the round loop's linear pick scan
    // goes quadratic and the event core's ordered index does not.
    let reqs = scale_workload(sessions, (sessions / 4).max(1), seed);
    let cfg = scale_config(core);
    let mut svc = SyntheticService::new(2.0, 0.1, 1.0).with_batch_marginal(0.2);
    let start = std::time::Instant::now();
    match core {
        CoreKind::Event => {
            let mut stats = run_streamed(&cfg, &mut svc, &reqs, SCALE_SAMPLE_CAP)?;
            let wall_ms = start.elapsed().as_secs_f64() * 1000.0;
            let exact_percentiles = stats.e2e.is_exact();
            Ok(ScaleCell {
                sessions,
                core,
                completed: stats.completed,
                preempted: stats.preempted,
                rejected: stats.rejected,
                requeued: stats.requeued,
                total_tokens: stats.total_tokens,
                makespan_ms: stats.makespan_ms,
                events: Some(stats.events),
                ticks: Some(stats.ticks),
                arena_bytes: Some(stats.arena_bytes),
                e2e: stats.e2e.summary(),
                exact_percentiles,
                wall_ms,
            })
        }
        CoreKind::RoundLoop => {
            let out = Scheduler::run_round_loop(&cfg, &mut svc, &reqs)?;
            let wall_ms = start.elapsed().as_secs_f64() * 1000.0;
            let (mut completed, mut preempted, mut rejected) = (0u64, 0u64, 0u64);
            let mut total_tokens = 0u64;
            let mut e2e = Histogram::default();
            for rec in &out.records {
                match rec.outcome {
                    SessionOutcome::Completed => completed += 1,
                    SessionOutcome::Preempted => preempted += 1,
                    SessionOutcome::Rejected => {
                        rejected += 1;
                        continue;
                    }
                }
                total_tokens += rec.tokens.len() as u64;
                e2e.push(rec.e2e_ms());
            }
            Ok(ScaleCell {
                sessions,
                core,
                completed,
                preempted,
                rejected,
                requeued: out.requeued,
                total_tokens,
                makespan_ms: out.makespan_ms,
                events: None,
                ticks: None,
                arena_bytes: None,
                e2e: e2e.summary(),
                exact_percentiles: true,
                wall_ms,
            })
        }
    }
}

/// Measure event-core throughput at every session count (and the round
/// loop's, at counts up to `round_cap` — its quadratic dispatch scan
/// makes larger counts impractical, which is the point of the
/// comparison). Cells run across `threads` scoped workers via
/// [`parallel_map`]; the result order is by cell index either way.
pub fn scale_sweep(
    sizes: &[usize],
    round_cap: usize,
    threads: usize,
    seed: u64,
) -> Result<Vec<ScaleCell>> {
    ensure!(!sizes.is_empty(), "scale sweep needs at least one session count");
    ensure!(sizes.iter().all(|&s| s >= 1), "session counts must be >= 1, got {sizes:?}");
    let mut cells: Vec<(usize, CoreKind)> =
        sizes.iter().map(|&s| (s, CoreKind::Event)).collect();
    cells.extend(sizes.iter().filter(|&&s| s <= round_cap).map(|&s| (s, CoreKind::RoundLoop)));
    parallel_map(&cells, threads, |_, &(sessions, core)| run_scale_cell(sessions, core, seed))
        .into_iter()
        .collect()
}

/// Parse a `--scale-sessions 1000,10000,...` list.
pub fn parse_scale_sessions(s: &str) -> Result<Vec<usize>> {
    let sizes: Vec<usize> = s
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| p.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad session count list {s:?}"))?;
    ensure!(!sizes.is_empty(), "--scale-sessions needs at least one session count");
    ensure!(sizes.iter().all(|&v| v >= 1), "session counts must be >= 1, got {sizes:?}");
    Ok(sizes)
}

/// Assemble the `BENCH_scale.json` document. Everything except the
/// `wall_*` keys is deterministic per seed at any `--threads` value —
/// the property the CI scale-smoke job diffs — so wall-clock is both
/// clearly labeled and excludable (`include_wall: false`, the CLI's
/// `--omit-wall`).
pub fn scale_json(
    cells: &[ScaleCell],
    sizes: &[usize],
    round_cap: usize,
    seed: u64,
    include_wall: bool,
) -> Json {
    let cell_json = |c: &ScaleCell| {
        let mut fields = vec![
            ("sessions", Json::Num(c.sessions as f64)),
            ("core", Json::Str(c.core.label().to_string())),
            ("completed", Json::Num(c.completed as f64)),
            ("preempted", Json::Num(c.preempted as f64)),
            ("rejected", Json::Num(c.rejected as f64)),
            ("requeued", Json::Num(c.requeued as f64)),
            ("total_tokens", Json::Num(c.total_tokens as f64)),
            ("makespan_ms", num(c.makespan_ms)),
            ("e2e_ms", c.e2e.to_json()),
            ("exact_percentiles", Json::Bool(c.exact_percentiles)),
        ];
        if let (Some(events), Some(ticks), Some(arena)) = (c.events, c.ticks, c.arena_bytes) {
            fields.push(("events", Json::Num(events as f64)));
            fields.push(("ticks", Json::Num(ticks as f64)));
            fields.push(("arena_bytes", Json::Num(arena as f64)));
            let eps =
                if c.makespan_ms > 0.0 { events as f64 * 1000.0 / c.makespan_ms } else { 0.0 };
            fields.push(("events_per_virtual_s", num(eps)));
        }
        if include_wall {
            fields.push(("wall_ms", num(c.wall_ms)));
            let wall_s = c.wall_ms / 1000.0;
            if wall_s > 0.0 {
                fields.push(("wall_sessions_per_s", num(c.sessions as f64 / wall_s)));
                if let Some(events) = c.events {
                    fields.push(("wall_events_per_s", num(events as f64 / wall_s)));
                }
            }
        }
        obj(fields)
    };
    obj(vec![
        ("bench", Json::Str("scale".to_string())),
        ("schema", Json::Str("odmoe.scale.v1".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("sizes", Json::Arr(sizes.iter().map(|&s| Json::Num(s as f64)).collect())),
        ("round_cap", Json::Num(round_cap as f64)),
        ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
    ])
}

/// Write a JSON document with a trailing newline.
pub fn write_bench(path: &Path, json: &Json) -> Result<()> {
    std::fs::write(path, format!("{json}\n")).with_context(|| format!("writing {path:?}"))
}

/// [`SyntheticService`] plus a synthetic routing signal: every session
/// routes each generated token to the globally hot expert 0 and to one
/// prompt-determined cold expert, and the resulting per-expert counts
/// are surfaced through [`ServiceModel::take_expert_demand`] — the same
/// channel `BatchEngineService` feeds from the real engine's load-dedup
/// tallies. The skew (half of all routed demand on one expert) is
/// exactly the regime popularity-driven replication exists for, so the
/// autoscale sweep can exercise the controller's replication actuator
/// without the PJRT runtime. Both sweep modes wrap the same inner
/// service, so measured timings cannot differ between them.
pub struct DemandService {
    inner: SyntheticService,
    n_experts: usize,
    demand: Vec<u64>,
}

impl DemandService {
    pub fn new(inner: SyntheticService, n_experts: usize) -> Self {
        assert!(n_experts >= 2, "need a hot and at least one cold expert");
        Self { inner, n_experts, demand: vec![0; n_experts] }
    }

    fn note(&mut self, reqs: &[&Request]) {
        for r in reqs {
            let tokens = r.out_tokens.max(1) as u64;
            let cold = 1 + r.prompt.first().copied().unwrap_or(0) as usize % (self.n_experts - 1);
            self.demand[0] += tokens;
            self.demand[cold] += tokens;
        }
    }
}

impl ServiceModel for DemandService {
    fn measure(&mut self, req: &Request) -> Result<SessionProfile> {
        self.note(&[req]);
        self.inner.measure(req)
    }

    fn measure_batch(&mut self, reqs: &[&Request]) -> Result<Vec<SessionProfile>> {
        self.note(reqs);
        self.inner.measure_batch(reqs)
    }

    fn take_expert_demand(&mut self) -> Option<Vec<u64>> {
        if self.demand.iter().all(|&d| d == 0) {
            return None;
        }
        Some(std::mem::replace(&mut self.demand, vec![0; self.n_experts]))
    }
}

/// One traffic-drift scenario of the autoscale sweep: a workload, the
/// static fleet shape it is served on, and the controller configuration
/// the reactive mode adds on top of that same shape.
pub struct AutoscaleScenario {
    pub name: String,
    pub spec: WorkloadSpec,
    pub sched: SchedulerConfig,
    pub control: ControlConfig,
}

/// The three drift scenarios (DESIGN.md §15), sized off the expected
/// span `requests / rate`: a diurnal swing whose peak slightly exceeds
/// the 2-replica static fleet, a flash crowd at 4x the base rate over
/// 15% of the span, and a rolling failure that kills one of the two
/// static replicas mid-run. The static shape is 2 replicas x batch 4;
/// the controller may float between 1 and 6 replicas against a 120 ms
/// p99-TTFT target.
pub fn autoscale_scenarios(requests: usize, rate: f64) -> Result<Vec<AutoscaleScenario>> {
    ensure!(requests >= 8, "autoscale scenarios need >= 8 requests, got {requests}");
    ensure!(rate.is_finite() && rate > 0.0, "rate must be finite and positive, got {rate}");
    let span_ms = requests as f64 / rate * 1000.0;
    let spec = |model: ArrivalModel| WorkloadSpec {
        model,
        n_requests: requests,
        prompt_len: LenDist::Bimodal { short: 16, long: 128, p_long: 0.5 },
        out_tokens: LenDist::Fixed(32),
        tenants: vec![TenantSpec::new("default", Slo::new(120.0, 15.0))],
        vocab: 256,
        shared_prompt: false,
    };
    let sched = SchedulerConfig {
        n_replicas: 2,
        max_batch: 4,
        queue_sample_stride: 16,
        ..SchedulerConfig::default()
    };
    let control = ControlConfig {
        epoch_ms: 250.0,
        target_p99_ttft_ms: 120.0,
        min_replicas: 1,
        max_replicas: 6,
        dispatch_width: 4,
        ..ControlConfig::default()
    };
    Ok(vec![
        AutoscaleScenario {
            name: "diurnal".into(),
            spec: spec(ArrivalModel::Diurnal {
                rate_per_s: rate,
                amplitude: 0.6,
                period_ms: (span_ms / 2.0).max(1.0),
                bursts: Vec::new(),
            }),
            sched: sched.clone(),
            control: control.clone(),
        },
        AutoscaleScenario {
            name: "flash-crowd".into(),
            spec: spec(ArrivalModel::Diurnal {
                rate_per_s: rate,
                amplitude: 0.2,
                period_ms: span_ms.max(1.0),
                bursts: vec![(0.30 * span_ms, 0.45 * span_ms, 4.0)],
            }),
            sched: sched.clone(),
            control: control.clone(),
        },
        AutoscaleScenario {
            name: "rolling-failure".into(),
            spec: spec(ArrivalModel::Poisson { rate_per_s: rate }),
            sched: SchedulerConfig { replica_failures: vec![(0, 0.35 * span_ms)], ..sched },
            control,
        },
    ])
}

/// One (scenario, mode) cell of the autoscale sweep.
#[derive(Debug, Clone)]
pub struct AutoscaleCell {
    pub scenario: String,
    pub mode: &'static str,
    pub report: ServeReport,
    pub requeued: usize,
    /// Fleet cost: ∫ live replicas dt for the reactive mode,
    /// `n_replicas x makespan` for the static fleet — the honest
    /// denominator under every latency win.
    pub replica_ms: f64,
    pub replication_bytes: u64,
    /// Token streams only — the controller moves capacity and timing,
    /// and this flags any run where it moved *which* tokens decode
    /// (requeue truncation under failure legitimately can).
    pub tokens_match_static: bool,
    pub control: Option<ControlReport>,
}

impl AutoscaleCell {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("mode", Json::Str(self.mode.to_string())),
            ("requeued", Json::Num(self.requeued as f64)),
            ("replica_ms", num(self.replica_ms)),
            ("replication_bytes", Json::Num(self.replication_bytes as f64)),
            ("tokens_match_static", Json::Bool(self.tokens_match_static)),
            ("serve", self.report.to_json()),
            (
                "control",
                match &self.control {
                    Some(r) => control_report_json(r),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// JSON rendering of a [`ControlReport`]: action tallies, costs, and
/// the per-epoch timeline the figure plots.
pub fn control_report_json(r: &ControlReport) -> Json {
    let epochs = r
        .epochs
        .iter()
        .map(|e| {
            obj(vec![
                ("t_ms", num(e.t_ms)),
                ("p99_ttft_ms", num(e.p99_ttft_ms)),
                ("queue_depth", Json::Num(e.queue_depth as f64)),
                ("live_replicas", Json::Num(e.live_replicas as f64)),
                ("completed", Json::Num(e.completed as f64)),
                ("action", Json::Str(e.action.to_string())),
            ])
        })
        .collect();
    obj(vec![
        ("scale_ups", Json::Num(r.scale_ups as f64)),
        ("scale_downs", Json::Num(r.scale_downs as f64)),
        ("reliefs", Json::Num(r.reliefs as f64)),
        ("tightens", Json::Num(r.tightens as f64)),
        ("replications", Json::Num(r.replications as f64)),
        ("migrated", Json::Num(r.migrated as f64)),
        ("replica_ms", num(r.replica_ms)),
        ("replication_bytes", Json::Num(r.replication_bytes as f64)),
        ("quality_debt_tokens", Json::Num(r.quality_debt_tokens as f64)),
        ("peak_replicas", Json::Num(r.peak_replicas as f64)),
        ("final_replicas", Json::Num(r.final_replicas as f64)),
        ("epochs", Json::Arr(epochs)),
    ])
}

/// Serve every drift scenario twice on the *same* generated arrival
/// stream — once on the static fleet (`control: None`, structurally the
/// uncontrolled scheduler) and once with the reactive controller — and
/// report both cells side by side. Both modes wrap the same
/// [`DemandService`], so the only degree of freedom between them is the
/// controller itself.
pub fn autoscale_sweep(requests: usize, rate: f64, seed: u64) -> Result<Vec<AutoscaleCell>> {
    let scenarios = autoscale_scenarios(requests, rate)?;
    let mut cells = Vec::with_capacity(scenarios.len() * 2);
    for sc in &scenarios {
        let tenant_names: Vec<String> = sc.spec.tenants.iter().map(|t| t.name.clone()).collect();
        let reqs = sc.spec.generate(seed);
        let mut run = |control: Option<ControlConfig>| -> Result<ServeOutcome> {
            let sched = SchedulerConfig { control, ..sc.sched.clone() };
            let inner = SyntheticService::new(5.0, 0.05, 3.0).with_batch_marginal(0.3);
            let mut svc = DemandService::new(inner, 8);
            Scheduler::run(&sched, &mut svc, &reqs)
        };
        let stat = run(None)?;
        let reactive = run(Some(sc.control.clone()))?;
        let streams = |o: &ServeOutcome| {
            let mut v: Vec<(u64, Vec<u32>)> =
                o.records.iter().map(|r| (r.id, r.tokens.clone())).collect();
            v.sort_by_key(|&(id, _)| id);
            v
        };
        let tokens_match = streams(&reactive) == streams(&stat);
        cells.push(AutoscaleCell {
            scenario: sc.name.clone(),
            mode: "static",
            report: ServeReport::from_outcome("static", rate, &stat, &tenant_names),
            requeued: stat.requeued,
            replica_ms: sc.sched.n_replicas as f64 * stat.makespan_ms,
            replication_bytes: 0,
            tokens_match_static: true,
            control: None,
        });
        let ctl = reactive.control.clone().expect("reactive run carries a control report");
        cells.push(AutoscaleCell {
            scenario: sc.name.clone(),
            mode: "reactive",
            report: ServeReport::from_outcome("reactive", rate, &reactive, &tenant_names),
            requeued: reactive.requeued,
            replica_ms: ctl.replica_ms,
            replication_bytes: ctl.replication_bytes,
            tokens_match_static: tokens_match,
            control: Some(ctl),
        });
    }
    Ok(cells)
}

/// Assemble the `BENCH_autoscale.json` document.
pub fn autoscale_json(cells: &[AutoscaleCell], requests: usize, rate: f64, seed: u64) -> Json {
    let mut names: Vec<String> = Vec::new();
    for c in cells {
        if !names.contains(&c.scenario) {
            names.push(c.scenario.clone());
        }
    }
    obj(vec![
        ("bench", Json::Str("autoscale".to_string())),
        ("schema", Json::Str("odmoe.autoscale.v1".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("requests", Json::Num(requests as f64)),
        ("rate_per_s", num(rate)),
        ("scenarios", Json::Arr(names.into_iter().map(Json::Str).collect())),
        (
            "modes",
            Json::Arr(vec![Json::Str("static".into()), Json::Str("reactive".into())]),
        ),
        ("cells", Json::Arr(cells.iter().map(|c| c.to_json()).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::SyntheticService;

    #[test]
    fn sweep_is_deterministic_and_covers_all_points() {
        let base = WorkloadSpec::poisson(1.0, 12, 256);
        let rates = [0.5, 2.0, 8.0];
        let sched = SchedulerConfig::default();
        let run = |seed| {
            let mut a = SyntheticService::new(20.0, 0.5, 30.0);
            let mut b = SyntheticService::new(10.0, 0.25, 15.0);
            let mut systems: Vec<(String, &mut dyn ServiceModel)> =
                vec![("slow".into(), &mut a), ("fast".into(), &mut b)];
            let results = rate_sweep(&mut systems, &base, &rates, &sched, seed).unwrap();
            sweep_json(&results, &base, &rates, &sched, seed).to_string()
        };
        let x = run(42);
        assert_eq!(x, run(42), "same seed must reproduce the file byte for byte");
        assert_ne!(x, run(43));
        assert!(x.contains("\"bench\":\"serve\""));
        assert!(x.contains("\"name\":\"slow\""));
        assert!(x.contains("\"p99\""));
        assert!(x.contains("\"goodput_tok_s\""));
    }

    #[test]
    fn parse_batches_injects_sequential_baseline() {
        assert_eq!(parse_batches("2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_batches("1,8").unwrap(), vec![1, 8]);
        assert!(parse_batches("0,2").is_err());
        assert!(parse_batches("").is_err());
    }

    #[test]
    fn parse_replica_failures_accepts_ms_suffix() {
        assert_eq!(
            parse_replica_failures("0@500,1@900ms").unwrap(),
            vec![(0, 500.0), (1, 900.0)]
        );
        assert!(parse_replica_failures("0").is_err(), "missing time");
        assert!(parse_replica_failures("x@5").is_err(), "bad index");
        assert!(parse_replica_failures("0@inf").is_err(), "non-finite time");
    }

    #[test]
    fn failover_sweep_is_deterministic_and_flags_token_drift() {
        use crate::coordinator::{BatchRunResult, PromptResult};
        // Synthetic engine: decode slows 20% per failed worker; one run
        // ("drift") returns a different stream to prove the flag trips.
        let fake = |k: usize, tokens: Vec<u32>| BatchRunResult {
            sessions: vec![PromptResult {
                ttft_ms: 100.0,
                decode_ms: 200.0 * (1.0 + 0.2 * k as f64),
                tokens,
                stall_ms: 5.0 * k as f64,
                ..PromptResult::default()
            }],
            expert_loads: 24,
            aborted_loads: 2,
            failovers: k as u64,
            decode_tokens: 8,
            decode_iterations: 8,
            decode_span_ms: 0.0,
            expert_demand: Vec::new(),
        };
        let run = || {
            let points =
                failover_sweep(3, |k| Ok(fake(k, vec![1, 2, 3]))).unwrap();
            failover_json(&points, 42, 8, 2, 0.0, 8).to_string()
        };
        let a = run();
        assert_eq!(a, run(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"failover\""));
        assert!(a.contains("\"failed_workers\":3"));
        assert!(a.contains("\"tokens_match_healthy\":true"));
        let points = failover_sweep(3, |k| Ok(fake(k, vec![1, 2, 3]))).unwrap();
        assert_eq!(points[0].slowdown, 1.0);
        for w in points.windows(2) {
            assert!(w[1].slowdown > w[0].slowdown);
        }
        // A run whose tokens drift under failure must be flagged.
        let drift =
            failover_sweep(1, |k| Ok(fake(k, if k == 0 { vec![1] } else { vec![2] }))).unwrap();
        assert!(!drift[1].tokens_match_healthy);
    }

    #[test]
    fn parallel_map_is_deterministic_and_ordered() {
        let items: Vec<usize> = (0..37).collect();
        let serial = parallel_map(&items, 1, |i, &v| (i, v * v));
        for threads in [2, 4, 16] {
            assert_eq!(parallel_map(&items, threads, |i, &v| (i, v * v)), serial);
        }
        assert_eq!(parallel_map::<usize, usize, _>(&[], 4, |_, &v| v), vec![]);
    }

    #[test]
    fn scale_sweep_cores_agree_and_threads_do_not_matter() {
        let sizes = [150usize, 300];
        let cells = scale_sweep(&sizes, 300, 1, 42).unwrap();
        assert_eq!(cells.len(), 4, "two event cells + two round cells under the cap");
        // Event and round cells at the same size must agree on every
        // deterministic quantity — the same equivalence the property
        // tests pin, surfaced through the sweep path.
        for &size in &sizes {
            let ev = cells
                .iter()
                .find(|c| c.sessions == size && c.core == CoreKind::Event)
                .expect("event cell");
            let rl = cells
                .iter()
                .find(|c| c.sessions == size && c.core == CoreKind::RoundLoop)
                .expect("round cell");
            assert_eq!(
                (ev.completed, ev.preempted, ev.rejected, ev.requeued, ev.total_tokens),
                (rl.completed, rl.preempted, rl.rejected, rl.requeued, rl.total_tokens)
            );
            assert_eq!(ev.makespan_ms, rl.makespan_ms);
            assert!(ev.exact_percentiles, "small cells stay in the exact regime");
            // Percentiles are individual sample values — bitwise equal.
            // The mean is a sum accumulated in different orders
            // (completion order vs. sorted record order), so only
            // near-equality holds for it.
            assert_eq!((ev.e2e.p50, ev.e2e.p95, ev.e2e.p99), (rl.e2e.p50, rl.e2e.p95, rl.e2e.p99));
            assert!((ev.e2e.mean - rl.e2e.mean).abs() <= 1e-9 * rl.e2e.mean.abs().max(1.0));
            assert!(ev.events.unwrap() > 0 && ev.arena_bytes.unwrap() > 0);
        }
        // The deterministic JSON section is byte-identical at any thread
        // count (and across repeat runs) once wall-clock is excluded.
        let json = |threads| {
            scale_json(&scale_sweep(&sizes, 300, threads, 42).unwrap(), &sizes, 300, 42, false)
                .to_string()
        };
        let one = json(1);
        assert_eq!(one, json(4), "--threads must not leak into the deterministic section");
        assert!(one.contains("\"bench\":\"scale\""));
        assert!(one.contains("\"events_per_virtual_s\""));
        assert!(!one.contains("wall_ms"), "wall keys excluded on --omit-wall");
        let with_wall =
            scale_json(&scale_sweep(&sizes, 0, 1, 42).unwrap(), &sizes, 0, 42, true).to_string();
        assert!(with_wall.contains("\"wall_ms\""));
        assert!(!with_wall.contains("\"core\":\"round-loop\""), "round cap 0 skips the oracle");
    }

    #[test]
    fn parse_scale_sessions_validates() {
        assert_eq!(parse_scale_sessions("1000,10000").unwrap(), vec![1000, 10000]);
        assert!(parse_scale_sessions("").is_err());
        assert!(parse_scale_sessions("0").is_err());
        assert!(parse_scale_sessions("a").is_err());
    }

    #[test]
    fn parse_chunk_and_depth_lists_inject_baselines() {
        assert_eq!(parse_chunk_counts("2,4,8").unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(parse_chunk_counts("1,4").unwrap(), vec![1, 4]);
        assert!(parse_chunk_counts("0,2").is_err());
        assert!(parse_chunk_counts("").is_err());
        assert_eq!(parse_depths("1,2").unwrap(), vec![0, 1, 2]);
        assert_eq!(parse_depths("0").unwrap(), vec![0]);
        assert!(parse_depths("").is_err());
    }

    #[test]
    fn overlap_sweep_is_deterministic_and_flags_token_drift() {
        use crate::coordinator::{BatchRunResult, PromptResult};
        // Synthetic engine: each chunk doubling shaves 5% off decode,
        // each depth step another 2%; tokens never change.
        let fake = |chunks: usize, depth: usize, tokens: Vec<u32>| BatchRunResult {
            sessions: vec![PromptResult {
                ttft_ms: 100.0,
                decode_ms: 320.0 * (1.0 - 0.05 * (chunks as f64).log2())
                    * (1.0 - 0.02 * depth as f64),
                tokens,
                stall_ms: 40.0 / chunks as f64,
                ..PromptResult::default()
            }],
            expert_loads: 24,
            aborted_loads: 2,
            failovers: 0,
            decode_tokens: 8,
            decode_iterations: 8,
            decode_span_ms: 0.0,
            expert_demand: Vec::new(),
        };
        let chunk_counts = [1usize, 2, 4, 8];
        let depths = [0usize, 1];
        let run = || {
            let points = overlap_sweep(&chunk_counts, &depths, 30.0, |c, d| {
                Ok(fake(c, d, vec![1, 2, 3]))
            })
            .unwrap();
            overlap_json(&points, 42, &chunk_counts, &depths, 8, 30.0).to_string()
        };
        let a = run();
        assert_eq!(a, run(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"overlap\""));
        assert!(a.contains("\"chunk_counts\":[1,2,4,8]"));
        assert!(a.contains("\"tokens_match_baseline\":true"));

        let points =
            overlap_sweep(&chunk_counts, &depths, 30.0, |c, d| Ok(fake(c, d, vec![1, 2, 3])))
                .unwrap();
        assert_eq!(points.len(), 8);
        assert_eq!((points[0].chunks, points[0].prefetch_depth), (1, 0));
        assert!((points[0].ms_per_token - 40.0).abs() < 1e-9);
        assert!((points[0].frac_of_fully_cached - 0.75).abs() < 1e-9);
        // ms/token strictly improves along the chunk axis at depth 0.
        for w in points[..4].windows(2) {
            assert!(w[1].ms_per_token < w[0].ms_per_token);
            assert!(w[1].frac_of_fully_cached > w[0].frac_of_fully_cached);
        }
        // A run whose tokens drift under chunking must be flagged.
        let drift = overlap_sweep(&[1, 2], &[0], 30.0, |c, _| {
            Ok(fake(c, 0, if c == 1 { vec![1] } else { vec![2] }))
        })
        .unwrap();
        assert!(!drift[1].tokens_match_baseline);
    }

    #[test]
    fn parse_cache_budgets_injects_cacheless_baseline() {
        assert_eq!(parse_cache_budgets("2,8").unwrap(), vec![0, 2, 8]);
        assert_eq!(parse_cache_budgets("0,64").unwrap(), vec![0, 64]);
        assert!(parse_cache_budgets("").is_err());
    }

    #[test]
    fn cache_reservation_shrinks_admission_budget_and_zero_is_identity() {
        let p = HardwareProfile::rtx3090();
        let base = MemoryModel::from_profile(&p, 24.0);
        let same = base.with_reservation(0);
        assert_eq!(same.budget_bytes, base.budget_bytes, "budget 0 must change nothing");
        let two = base.with_reservation(2 * p.expert_bytes as u64);
        assert_eq!(two.budget_bytes, base.budget_bytes - 2 * p.expert_bytes as u64);
        assert_eq!(two.kv_bytes_per_token, base.kv_bytes_per_token);
        // Oversized reservations saturate instead of wrapping.
        assert_eq!(base.with_reservation(u64::MAX).budget_bytes, 0);
    }

    #[test]
    fn cache_sweep_is_deterministic_and_flags_token_drift() {
        use crate::coordinator::{BatchRunResult, PromptResult};
        // Synthetic engine: each hot slot absorbs one of 8 loads/iter
        // and shaves decode toward the 240 ms fully-cached floor.
        let fake = |budget: usize, tokens: Vec<u32>| {
            let hot = budget.min(8) as f64;
            BatchRunResult {
                sessions: vec![PromptResult {
                    ttft_ms: 100.0,
                    decode_ms: 320.0 - 10.0 * hot,
                    tokens,
                    stall_ms: 40.0 * (1.0 - hot / 8.0),
                    ..PromptResult::default()
                }],
                expert_loads: (8 * (8 - budget.min(8))) as u64,
                aborted_loads: 0,
                failovers: 0,
                decode_tokens: 8,
                decode_iterations: 8,
                decode_span_ms: 0.0,
                expert_demand: Vec::new(),
            }
        };
        let budgets = [0usize, 2, 8];
        let run = || {
            let points =
                cache_sweep(&budgets, 30.0, |b| Ok(fake(b, vec![1, 2, 3]))).unwrap();
            cache_json(&points, 42, &budgets, "uniform:8", "lru:h8w0c0", 8, 30.0).to_string()
        };
        let a = run();
        assert_eq!(a, run(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"cache\""));
        assert!(a.contains("\"cache_budgets\":[0,2,8]"));
        assert!(a.contains("\"tokens_match_baseline\":true"));

        let points =
            cache_sweep(&budgets, 30.0, |b| Ok(fake(b, vec![1, 2, 3]))).unwrap();
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].budget, 0);
        assert!((points[0].ms_per_token - 40.0).abs() < 1e-9);
        assert!((points[0].loads_per_token - 8.0).abs() < 1e-9);
        // ms/token and loads/token strictly improve with hot budget.
        for w in points.windows(2) {
            assert!(w[1].ms_per_token < w[0].ms_per_token);
            assert!(w[1].loads_per_token < w[0].loads_per_token);
            assert!(w[1].frac_of_fully_cached > w[0].frac_of_fully_cached);
        }
        // The whole working set resident: no loads at all.
        assert_eq!(points[2].loads_per_token, 0.0);
        // A sweep without the budget-0 pin is rejected.
        assert!(cache_sweep(&[2, 8], 30.0, |b| Ok(fake(b, vec![1]))).is_err());
        // A run whose tokens drift under caching must be flagged.
        let drift = cache_sweep(&[0, 4], 30.0, |b| {
            Ok(fake(b, if b == 0 { vec![1] } else { vec![2] }))
        })
        .unwrap();
        assert!(!drift[1].tokens_match_baseline);
    }

    #[test]
    fn parse_policy_and_fleet_grids_validate() {
        assert_eq!(
            parse_policy_grid("static,slack,slack-importance").unwrap(),
            vec![
                PrecisionPolicy::Static,
                PrecisionPolicy::Slack,
                PrecisionPolicy::SlackImportance
            ]
        );
        // The static baseline is prepended when absent.
        assert_eq!(
            parse_policy_grid("slack").unwrap(),
            vec![PrecisionPolicy::Static, PrecisionPolicy::Slack]
        );
        assert!(parse_policy_grid("").is_err());
        assert!(parse_policy_grid("fp16").is_err(), "precision names are not policies");
        assert_eq!(
            parse_fleet_grid("uniform|jetson:4,nano:2").unwrap(),
            vec!["uniform".to_string(), "jetson:4,nano:2".to_string()]
        );
        assert!(parse_fleet_grid("||").is_err());
    }

    #[test]
    fn precision_sweep_is_deterministic_and_pins_static() {
        // Synthetic engine: slack shaves 10% off decode, the
        // importance-aware policy 15% plus one skipped expert (which
        // also perturbs the token stream); fidelity degrades with the
        // downgrade depth.
        let fake = |policy: PrecisionPolicy, fleet: &str| {
            let (gain, skipped, debt, tokens) = match policy {
                PrecisionPolicy::Static => (1.0, 0, 0.0, vec![1u32, 2, 3]),
                PrecisionPolicy::Slack => (0.9, 0, 0.004, vec![1, 2, 3]),
                PrecisionPolicy::SlackImportance => (0.85, 2, 0.011, vec![1, 2, 4]),
            };
            let slow = if fleet == "uniform" { 1.0 } else { 1.5 };
            PrecisionMeasurement {
                decode_ms: 320.0 * gain * slow,
                decode_tokens: 8,
                loads: match policy {
                    PrecisionPolicy::Static => [96, 0, 0],
                    PrecisionPolicy::Slack => [40, 32, 24],
                    PrecisionPolicy::SlackImportance => [30, 40, 24],
                },
                skipped_experts: skipped,
                upgrade_reloads: 0,
                quality_debt_frac: debt,
                token_match_rate: 1.0 - debt,
                mean_kl: debt * 0.1,
                tokens,
            }
        };
        let fleets = vec!["uniform".to_string(), "jetson:4,nano:2".to_string()];
        let policies = parse_policy_grid("static,slack,slack-importance").unwrap();
        let rates = [2.0];
        let run = || {
            let cells = precision_sweep(&fleets, &policies, &rates, |f, p, _| Ok(fake(p, f)))
                .unwrap();
            precision_json(&cells, 42, &fleets, &policies, &rates, 8).to_string()
        };
        let a = run();
        assert_eq!(a, run(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"precision\""));
        assert!(a.contains("\"policy\":\"slack-importance\""));
        assert!(a.contains("\"loads_int8\":32"));

        let cells =
            precision_sweep(&fleets, &policies, &rates, |f, p, _| Ok(fake(p, f))).unwrap();
        assert_eq!(cells.len(), 6, "policy x fleet x rate");
        // The static cell is its own baseline: speedup exactly 1, tokens
        // trivially matching.
        let stat = &cells[0];
        assert_eq!(stat.policy, PrecisionPolicy::Static);
        assert_eq!(stat.speedup_vs_static, 1.0);
        assert!(stat.tokens_match_static);
        // Transfer-only downgrades speed decode up without token drift...
        let slack = &cells[1];
        assert!(slack.speedup_vs_static > 1.0);
        assert!(slack.tokens_match_static, "transfer precision must not move tokens");
        // ...while the skipping policy is faster still and honestly
        // flags its token drift and quality debt.
        let si = &cells[2];
        assert!(si.speedup_vs_static > slack.speedup_vs_static);
        assert!(!si.tokens_match_static);
        assert!(si.meas.quality_debt_frac > slack.meas.quality_debt_frac);
        // A sweep without the static pin is rejected.
        assert!(precision_sweep(
            &fleets,
            &[PrecisionPolicy::Slack],
            &rates,
            |f, p, _| Ok(fake(p, f))
        )
        .is_err());
    }

    #[test]
    fn attribution_sweep_aggregates_and_is_deterministic() {
        use crate::trace::{EventKind, Trace};
        // Synthetic one-token decode per rate: main [0,4), expert load
        // [2, 10+rate) — the load binds the token.
        let mk = |rate: f64| {
            let mut t = Trace::new();
            t.enabled = true;
            t.push(EventKind::MainCompute, 0, 0.0, 4.0, "M");
            t.push(EventKind::ExpertLoad, 2, 2.0, 10.0 + rate, "EL");
            let attrib = crate::telemetry::attribute(&t, &[(0.0, 10.0 + rate)]);
            Ok((3usize, attrib))
        };
        let rates = [0.5, 2.0];
        let run = || {
            let points = attribution_sweep(&rates, mk).unwrap();
            attrib_json(&points, 42, "uniform:8").to_string()
        };
        let a = run();
        assert_eq!(a, run(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"attrib\""));
        assert!(a.contains("\"fleet\":\"uniform:8\""));
        assert!(a.contains("\"bound\":\"expert_load\""));
        let points = attribution_sweep(&rates, mk).unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].sessions, 3);
        assert_eq!(points[0].tokens, 1);
        assert_eq!(points[0].bound(), Phase::ExpertLoad);
        assert!((points[0].total_ms() - 10.5).abs() < 1e-9, "phases partition the window");
        assert!(attribution_sweep(&[], mk).is_err(), "empty rate list rejected");
    }

    #[test]
    fn demand_service_skews_and_drains_the_routing_signal() {
        let mut s = DemandService::new(SyntheticService::new(5.0, 0.05, 3.0), 8);
        assert!(s.take_expert_demand().is_none(), "untouched service has no signal");
        let reqs: Vec<Request> =
            (0..6).map(|i| Request::open_loop(i, vec![i as u32 + 1, 2, 3], 8, 0.0)).collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        s.measure_batch(&refs[..4]).unwrap();
        s.measure(refs[4]).unwrap();
        s.measure(refs[5]).unwrap();
        let d = s.take_expert_demand().expect("routed demand present");
        assert_eq!(d.len(), 8);
        assert_eq!(d[0], 6 * 8, "the hot expert sees every session's tokens");
        assert_eq!(d.iter().sum::<u64>(), 2 * 6 * 8, "top-2 routing: twice the token count");
        assert!(s.take_expert_demand().is_none(), "the drain resets the tallies");
    }

    #[test]
    fn autoscale_scenarios_cover_the_three_drifts() {
        let scs = autoscale_scenarios(48, 24.0).unwrap();
        let names: Vec<&str> = scs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["diurnal", "flash-crowd", "rolling-failure"]);
        for sc in &scs {
            assert!(sc.sched.control.is_none(), "the scenario shape itself is uncontrolled");
            assert_eq!(sc.sched.n_replicas, 2);
            assert!(sc.control.max_replicas > sc.sched.n_replicas);
        }
        let (ri, at) = scs[2].sched.replica_failures[0];
        assert_eq!(ri, 0);
        assert!((at - 700.0).abs() < 1e-6, "failure at 35% of the 2s span, got {at}");
        assert!(autoscale_scenarios(4, 24.0).is_err());
        assert!(autoscale_scenarios(48, 0.0).is_err());
    }

    #[test]
    fn autoscale_sweep_is_deterministic_and_pairs_static_with_reactive() {
        let run = |seed| {
            let cells = autoscale_sweep(48, 24.0, seed).unwrap();
            autoscale_json(&cells, 48, 24.0, seed).to_string()
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed must reproduce the file byte for byte");
        assert_ne!(a, run(43));
        assert!(a.contains("\"schema\":\"odmoe.autoscale.v1\""));
        assert!(a.contains("\"scenario\":\"flash-crowd\""));

        let cells = autoscale_sweep(48, 24.0, 42).unwrap();
        assert_eq!(cells.len(), 6, "three scenarios x two modes");
        for pair in cells.chunks(2) {
            let (stat, reactive) = (&pair[0], &pair[1]);
            assert_eq!(stat.scenario, reactive.scenario);
            assert_eq!(stat.mode, "static");
            assert_eq!(reactive.mode, "reactive");
            // The static cell is structurally uncontrolled and its own
            // token reference; the reactive cell carries the full
            // decision timeline and its costs.
            assert!(stat.control.is_none());
            assert!(stat.tokens_match_static);
            let ctl = reactive.control.as_ref().expect("reactive control report");
            assert!(!ctl.epochs.is_empty(), "the run spans multiple control epochs");
            assert!(reactive.replica_ms > 0.0);
            assert!(stat.replica_ms > 0.0);
            // Every session is accounted for in both modes.
            assert_eq!(stat.report.offered, 48);
            assert_eq!(reactive.report.offered, 48);
        }
    }

    #[test]
    fn batch_sweep_is_deterministic_and_tagged() {
        let base = WorkloadSpec::poisson(4.0, 16, 256);
        let batches = [1usize, 2, 4];
        let rates = [2.0, 8.0];
        let sched = SchedulerConfig::default();
        let run = |seed| {
            let mut s = SyntheticService::new(20.0, 0.5, 30.0).with_batch_marginal(0.1);
            let mut systems: Vec<(String, &mut dyn ServiceModel)> =
                vec![("synthetic".into(), &mut s)];
            let results = batch_sweep(&mut systems, &base, &batches, &rates, &sched, seed).unwrap();
            batch_sweep_json(&results, &base, &batches, &rates, &sched, seed).to_string()
        };
        let x = run(42);
        assert_eq!(x, run(42), "same seed must reproduce the file byte for byte");
        assert!(x.contains("\"bench\":\"batch\""));
        assert!(x.contains("\"batches\":[1,2,4]"));
        assert!(x.contains("\"max_batch\":1"));
        assert!(x.contains("\"max_batch\":4"));
    }
}
