//! Event-driven scheduler core (DESIGN.md §13): the heap-based executor
//! behind [`Scheduler::run`].
//!
//! The round loop in [`super::scheduler`] re-scans every replica's
//! running list on every clock step and keeps its pending arrivals in a
//! sorted `Vec` with O(n) inserts — fine at thousands of sessions,
//! hopeless at the ROADMAP's million-session scale. This module replaces
//! the executor while keeping the round loop's *observable behavior as
//! the spec*: records, outcomes, tokens, queue-depth samples, busy time
//! and requeue counts are reproduced bit-identically (pinned by
//! `rust/tests/event_core_props.rs`), and the round loop survives as the
//! equivalence oracle behind [`CoreKind::RoundLoop`].
//!
//! Mechanics:
//!
//! * **One min-heap of [`Event`]s** — arrivals (open-loop and
//!   closed-loop chain releases), batch-member completions, and replica
//!   fail-stops — ordered by `(time, kind, request id)` with kind codes
//!   chosen so a tick drains completions, then failures, then arrivals:
//!   exactly the round loop's phase order. Push and pop are O(log n).
//! * **Struct-of-arrays [`SessionArena`]** — `eligible_at`, `state`,
//!   `epoch`, `owner`, `session_bytes` and `record` columns preallocated
//!   once per run; the hot path allocates nothing per event. Stale
//!   completion events (their session re-queued when a replica died) are
//!   invalidated by an epoch counter instead of a heap search, and a
//!   stale-only clock stop runs no phases at all — provably a no-op, so
//!   the tick counter (and with it the stride-sampled queue-depth trace)
//!   stays in lockstep with the round loop.
//! * **Pluggable record sink** — [`run`] collects full
//!   [`SessionRecord`]s for a [`ServeOutcome`]; [`run_streamed`] folds
//!   each record into bounded summaries ([`ScaleStats`], backed by
//!   [`BoundedHistogram`]) the moment it completes, so a million-session
//!   run never holds a million records.
//!
//! Determinism caveat: dispatch picks the key-minimal admitted session
//! via an ordered set, relying on policy keys being unique — which they
//! are whenever request ids are unique (every generator in this repo
//! assigns ids `0..n`). The round loop's linear scan breaks exact-key
//! ties by replica index instead; duplicate-id workloads are outside the
//! equivalence contract.
//!
//! [`Scheduler::run`]: super::scheduler::Scheduler::run
//! [`CoreKind::RoundLoop`]: super::scheduler::CoreKind::RoundLoop

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use anyhow::{bail, ensure, Result};

use super::metrics::{BoundedHistogram, WindowedHistogram};
use super::scheduler::{
    truncate, QueueKey, SchedulerConfig, ServeOutcome, ServiceModel, SessionOutcome, SessionRecord,
};
use super::Request;
use crate::cluster::{Ms, Node};
use crate::control::{
    plan_replication, ControlConfig, ControlReport, ControlState, EpochObservation, EpochSnapshot,
};

/// Min-heap over `(time, request id, request index)` pending-arrival
/// entries: the shared replacement for the old sorted-`Vec` +
/// `insert_future` pair (O(n) per insert). Pop order matches the old
/// comparator exactly — earliest time first, ties by request id — which
/// `futureheap_pops_in_old_comparator_order` pins below.
#[derive(Debug, Default)]
pub(crate) struct FutureHeap {
    heap: BinaryHeap<Reverse<FutureEntry>>,
}

#[derive(Debug, Clone, Copy)]
struct FutureEntry(Ms, u64, usize);

impl PartialEq for FutureEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for FutureEntry {}

impl PartialOrd for FutureEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FutureEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Times are never NaN (they come from finite arrival/think
        // arithmetic), so total_cmp agrees with the old partial_cmp
        // comparator; the index tie-break only keeps the order total.
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1)).then(self.2.cmp(&other.2))
    }
}

impl FutureHeap {
    pub(crate) fn with_capacity(n: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(n) }
    }

    pub(crate) fn push(&mut self, e: (Ms, u64, usize)) {
        self.heap.push(Reverse(FutureEntry(e.0, e.1, e.2)));
    }

    /// The earliest pending entry, if any.
    pub(crate) fn peek(&self) -> Option<(Ms, u64, usize)> {
        self.heap.peek().map(|&Reverse(FutureEntry(t, id, idx))| (t, id, idx))
    }

    pub(crate) fn pop(&mut self) -> Option<(Ms, u64, usize)> {
        self.heap.pop().map(|Reverse(FutureEntry(t, id, idx))| (t, id, idx))
    }
}

// Event kind codes double as the intra-tick phase order (the heap pops
// same-time events kind-ascending): completions before failures — a
// session finishing exactly at the failure instant counts as completed —
// before arrivals. Matches round-loop phases 1, 1b, 2.
const EV_COMPLETION: u8 = 0;
const EV_FAILURE: u8 = 1;
const EV_ARRIVAL: u8 = 2;
/// Controller epoch boundary (`--control reactive` only; an off run
/// never pushes one). Highest kind code: the controller observes an
/// instant *after* that instant's completions, failures and arrivals
/// have landed, so its queue/busy readings are the settled state.
const EV_CONTROL: u8 = 3;

/// One scheduled occurrence. `id` is the request id (or the replica
/// index for failures), `idx` the arena row (or replica index), `epoch`
/// the session's requeue generation at push time — a completion whose
/// epoch no longer matches the arena's is stale and skipped.
#[derive(Debug, Clone, Copy)]
struct Event {
    time: Ms,
    kind: u8,
    id: u64,
    idx: usize,
    epoch: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.kind.cmp(&other.kind))
            .then(self.id.cmp(&other.id))
            .then(self.idx.cmp(&other.idx))
            .then(self.epoch.cmp(&other.epoch))
    }
}

/// Session lifecycle, one byte per row in the arena's `state` column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SessState {
    /// Not yet eligible (future arrival or gated behind its chain).
    Pending,
    Waiting,
    Admitted,
    Running,
    Done,
}

/// Struct-of-arrays session state: every column preallocated at run
/// start, indexed by request position. Holding per-session state in
/// parallel columns (instead of a `Vec` of structs or per-event boxes)
/// keeps the hot path allocation-free and makes the run's resident
/// footprint a closed form — [`SessionArena::footprint_bytes`], the
/// peak-RSS proxy `BENCH_scale.json` reports.
struct SessionArena {
    eligible_at: Vec<Ms>,
    state: Vec<SessState>,
    /// Requeue generation; bumped when a replica failure re-queues the
    /// session, invalidating its in-heap completion event.
    epoch: Vec<u32>,
    /// Replica owning the session's ledger bytes (meaningful in
    /// Admitted/Running states).
    owner: Vec<usize>,
    /// Admission footprint, precomputed once.
    session_bytes: Vec<u64>,
    records: Vec<Option<SessionRecord>>,
}

impl SessionArena {
    fn new(cfg: &SchedulerConfig, requests: &[Request]) -> Self {
        let n = requests.len();
        Self {
            eligible_at: vec![0.0; n],
            state: vec![SessState::Pending; n],
            epoch: vec![0; n],
            owner: vec![usize::MAX; n],
            session_bytes: requests.iter().map(|r| cfg.memory.session_bytes(r)).collect(),
            records: vec![None; n],
        }
    }

    /// Resident bytes of the arena columns (capacity × element size) —
    /// the peak-RSS proxy. Record payloads (tokens) are excluded: they
    /// are per-session transients the streaming sink drops at
    /// completion, not steady arena state.
    fn footprint_bytes(&self) -> u64 {
        use std::mem::size_of;
        (self.eligible_at.capacity() * size_of::<Ms>()
            + self.state.capacity() * size_of::<SessState>()
            + self.epoch.capacity() * size_of::<u32>()
            + self.owner.capacity() * size_of::<usize>()
            + self.session_bytes.capacity() * size_of::<u64>()
            + self.records.capacity() * size_of::<Option<SessionRecord>>()) as u64
    }
}

/// Where finished records go: [`run`] collects them whole,
/// [`run_streamed`] folds them into bounded summaries and drops them.
trait RecordSink {
    fn emit(&mut self, rec: SessionRecord);
}

#[derive(Default)]
struct CollectSink {
    records: Vec<SessionRecord>,
}

impl RecordSink for CollectSink {
    fn emit(&mut self, rec: SessionRecord) {
        self.records.push(rec);
    }
}

/// Streaming sink: outcome counts, token totals, and bounded e2e/TTFT
/// histograms. Mirrors [`super::metrics::ServeReport`]'s aggregation
/// conventions (rejected sessions are counted, then skipped).
struct StreamSink {
    completed: u64,
    preempted: u64,
    rejected: u64,
    total_tokens: u64,
    e2e: BoundedHistogram,
    ttft: BoundedHistogram,
}

impl StreamSink {
    fn new(sample_cap: usize) -> Self {
        Self {
            completed: 0,
            preempted: 0,
            rejected: 0,
            total_tokens: 0,
            e2e: BoundedHistogram::new(sample_cap),
            ttft: BoundedHistogram::new(sample_cap),
        }
    }
}

impl RecordSink for StreamSink {
    fn emit(&mut self, rec: SessionRecord) {
        match rec.outcome {
            SessionOutcome::Completed => self.completed += 1,
            SessionOutcome::Preempted => self.preempted += 1,
            SessionOutcome::Rejected => {
                self.rejected += 1;
                return;
            }
        }
        self.total_tokens += rec.tokens.len() as u64;
        self.e2e.push(rec.e2e_ms());
        if let Some(t) = rec.ttft_ms() {
            self.ttft.push(t);
        }
    }
}

/// Bounded-memory summary of one streamed run — what
/// `od-moe serve --scale-sweep` reports per cell.
#[derive(Debug, Clone)]
pub struct ScaleStats {
    pub completed: u64,
    pub preempted: u64,
    pub rejected: u64,
    /// Sessions re-queued by replica failures (same meaning as
    /// [`ServeOutcome::requeued`]).
    pub requeued: usize,
    /// Generated tokens across completed + preempted sessions.
    pub total_tokens: u64,
    pub makespan_ms: Ms,
    /// Events popped from the heap (arrivals, completions including
    /// stale ones, failures) — the throughput denominator.
    pub events: u64,
    /// Scheduling ticks: clock stops where at least one phase ran.
    pub ticks: u64,
    /// Arena column footprint, the peak-RSS proxy.
    pub arena_bytes: u64,
    /// End-to-end latency; exact percentiles up to the sample cap,
    /// log-binned above it ([`BoundedHistogram::is_exact`]).
    pub e2e: BoundedHistogram,
    pub ttft: BoundedHistogram,
}

/// What [`run_core`] produced besides the sink's records.
struct CoreOutcome {
    makespan_ms: Ms,
    queue_depth: Vec<(Ms, usize)>,
    replica_busy_ms: Vec<Ms>,
    bookings: Vec<Vec<(Ms, Ms, u64)>>,
    requeued: usize,
    events: u64,
    ticks: u64,
    arena_bytes: u64,
    /// The controller's action log + cost ledger (`--control reactive`
    /// only; None on off runs).
    control: Option<ControlReport>,
}

struct EventReplica {
    node: Node,
    /// In-flight sessions of the current batch: (arena row, finish time).
    running: Vec<(usize, Ms)>,
    busy_ms: Ms,
    bookings: Vec<(Ms, Ms, u64)>,
    dead: bool,
    /// Retired by a controller scale-down: stops admitting and
    /// dispatching but drains its running batch (unlike `dead`, which
    /// aborts it). A later scale-up un-retires the replica, ledger and
    /// busy-time history intact.
    retired: bool,
}

/// Live replicas = alive and accepting work (neither failed nor
/// retired) — the controller's fleet size and the admission target set.
fn live_replicas(reps: &[EventReplica]) -> usize {
    reps.iter().filter(|r| !r.dead && !r.retired).count()
}

/// Controller state threaded through one event-core run. Built only
/// under `--control reactive` — an off run constructs none of this (the
/// PR 8/9 structural pin: off is the absence of the mechanism).
struct ControlRuntime {
    cfg: ControlConfig,
    state: ControlState,
    /// Rolling arrival→first-token window the epoch p99 is read from
    /// (samples land at dispatch, when the first-token time is known).
    ttft: WindowedHistogram,
    /// Completions since the last epoch boundary.
    epoch_completed: u64,
    /// Per-expert demand accumulated from the service model's load-dedup
    /// tallies ([`ServiceModel::take_expert_demand`]) since run start.
    demand: Vec<u64>,
    /// Service-time factor from active precision relief (1.0 = off;
    /// [`ControlConfig::relief_scale`] while on — non-compounding).
    relief_scale: f64,
    /// Service-time factor from the one-shot expert replication.
    replication_scale: f64,
    /// In-flight session cap while admission is tightened.
    admission_cap: Option<usize>,
    /// ∫ live dt bookkeeping: integral is advanced at every fleet-size
    /// change and finalized at the makespan.
    live_since: Ms,
    live_count: usize,
    report: ControlReport,
}

impl ControlRuntime {
    fn new(cfg: ControlConfig, initial_live: usize) -> Self {
        let window = cfg.window;
        Self {
            cfg,
            state: ControlState::default(),
            ttft: WindowedHistogram::new(window),
            epoch_completed: 0,
            demand: Vec::new(),
            relief_scale: 1.0,
            replication_scale: 1.0,
            admission_cap: None,
            live_since: 0.0,
            live_count: initial_live,
            report: ControlReport { peak_replicas: initial_live, ..ControlReport::default() },
        }
    }

    /// Combined factor applied to measured service durations at
    /// dispatch: precision relief × replication speedup.
    fn time_scale(&self) -> f64 {
        self.relief_scale * self.replication_scale
    }

    /// Advance the replica-ms integral to `t`, with `live` replicas
    /// live from `t` on.
    fn note_live(&mut self, t: Ms, live: usize) {
        self.report.replica_ms += (t - self.live_since).max(0.0) * self.live_count as f64;
        self.live_since = t;
        self.live_count = live;
        self.report.peak_replicas = self.report.peak_replicas.max(live);
    }

    fn finalize(mut self, t: Ms) -> ControlReport {
        let live = self.live_count;
        self.note_live(t, live);
        self.report.final_replicas = live;
        self.report
    }

    /// Fold one drained demand vector into the cross-epoch accumulator.
    fn merge_demand(&mut self, d: &[u64]) {
        if d.len() > self.demand.len() {
            self.demand.resize(d.len(), 0);
        }
        for (acc, &v) in self.demand.iter_mut().zip(d) {
            *acc += v;
        }
    }
}

/// Full-fidelity run: collect every record and return the same
/// [`ServeOutcome`] the round loop produces (completion order: finish
/// time, then id).
pub fn run(
    cfg: &SchedulerConfig,
    service: &mut dyn ServiceModel,
    requests: &[Request],
) -> Result<ServeOutcome> {
    let mut sink = CollectSink::default();
    let core = run_core(cfg, service, requests, &mut sink, false)?;
    let mut records = sink.records;
    records.sort_by(|a, b| {
        a.finish_ms.partial_cmp(&b.finish_ms).unwrap_or(Ordering::Equal).then(a.id.cmp(&b.id))
    });
    Ok(ServeOutcome {
        records,
        makespan_ms: core.makespan_ms,
        queue_depth: core.queue_depth,
        replica_busy_ms: core.replica_busy_ms,
        bookings: core.bookings,
        requeued: core.requeued,
        control: core.control,
    })
}

/// Bounded-memory run for scale sweeps: records fold into
/// [`ScaleStats`] as they complete (exact percentiles up to
/// `sample_cap` samples per series, log-binned summaries above), and
/// per-replica booking logs are skipped. Scheduling decisions are
/// identical to [`run`] — only what is *retained* differs.
pub fn run_streamed(
    cfg: &SchedulerConfig,
    service: &mut dyn ServiceModel,
    requests: &[Request],
    sample_cap: usize,
) -> Result<ScaleStats> {
    let mut sink = StreamSink::new(sample_cap);
    let core = run_core(cfg, service, requests, &mut sink, true)?;
    Ok(ScaleStats {
        completed: sink.completed,
        preempted: sink.preempted,
        rejected: sink.rejected,
        requeued: core.requeued,
        total_tokens: sink.total_tokens,
        makespan_ms: core.makespan_ms,
        events: core.events,
        ticks: core.ticks,
        arena_bytes: core.arena_bytes,
        e2e: sink.e2e,
        ttft: sink.ttft,
    })
}

/// The event loop proper. `lean` skips the per-replica booking logs
/// (unbounded at scale); everything else is retained identically.
fn run_core<S: RecordSink>(
    cfg: &SchedulerConfig,
    service: &mut dyn ServiceModel,
    requests: &[Request],
    sink: &mut S,
    lean: bool,
) -> Result<CoreOutcome> {
    assert!(cfg.n_replicas > 0, "need at least one replica");
    assert!(cfg.max_batch > 0, "need a positive batch limit");
    let n = requests.len();
    let stride = cfg.queue_sample_stride.max(1) as u64;

    // Closed-loop chains: per client, requests become eligible in id
    // order, each gated behind its predecessor's completion plus think
    // time — the round loop's construction, verbatim.
    let mut chains: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut by_id: Vec<usize> = (0..n).collect();
    by_id.sort_by_key(|&i| requests[i].id);
    for &i in &by_id {
        chains.entry(requests[i].client).or_default().push(i);
    }
    let mut chain_pos: BTreeMap<u64, usize> = BTreeMap::new();
    let mut events: BinaryHeap<Reverse<Event>> = BinaryHeap::with_capacity(n + cfg.n_replicas);
    for (client, chain) in &chains {
        let idx = chain[0];
        events.push(Reverse(Event {
            time: requests[idx].arrival_ms,
            kind: EV_ARRIVAL,
            id: requests[idx].id,
            idx,
            epoch: 0,
        }));
        chain_pos.insert(*client, 1);
    }

    let mut fail_at: Vec<Ms> = vec![f64::INFINITY; cfg.n_replicas];
    for &(ri, at) in &cfg.replica_failures {
        ensure!(ri < cfg.n_replicas, "replica failure targets replica {ri} of {}", cfg.n_replicas);
        ensure!(at.is_finite() && at >= 0.0, "bad replica failure time {at}");
        fail_at[ri] = fail_at[ri].min(at);
    }
    for (ri, &at) in fail_at.iter().enumerate() {
        if at.is_finite() {
            events.push(Reverse(Event {
                time: at,
                kind: EV_FAILURE,
                id: ri as u64,
                idx: ri,
                epoch: 0,
            }));
        }
    }

    let mut reps: Vec<EventReplica> = (0..cfg.n_replicas)
        .map(|i| EventReplica {
            node: Node::new(i),
            running: Vec::new(),
            busy_ms: 0.0,
            bookings: Vec::new(),
            dead: false,
            retired: false,
        })
        .collect();
    let mut arena = SessionArena::new(cfg, requests);
    let arena_bytes = arena.footprint_bytes();

    // --control reactive: build the controller and seed the first epoch
    // boundary. --control off builds nothing and pushes nothing — every
    // clock stop, tick and sample stays byte-identical to a build
    // without this feature.
    let mut control: Option<ControlRuntime> = match &cfg.control {
        Some(c) => {
            c.validate()?;
            ensure!(
                (c.min_replicas..=c.max_replicas).contains(&cfg.n_replicas),
                "--control replica budget {}..={} must contain the starting fleet of {}",
                c.min_replicas,
                c.max_replicas,
                cfg.n_replicas
            );
            events.push(Reverse(Event {
                time: c.epoch_ms,
                kind: EV_CONTROL,
                id: 0,
                idx: 0,
                epoch: 0,
            }));
            Some(ControlRuntime::new(c.clone(), cfg.n_replicas))
        }
        None => None,
    };
    // Set when a control event fires; the next boundary is pushed after
    // the tick's phases so the heap-emptiness stall check stays sound.
    let mut control_due: Option<Ms> = None;

    // Waiting queue and admitted set are ordered indexes over (policy
    // key, arena row). The admitted set is global (the round loop kept
    // per-replica lists) with the owning replica in the arena's `owner`
    // column — dispatch wants the key-minimal entry across all replicas
    // anyway, so one ordered set answers it in O(log n).
    let mut waiting: BTreeSet<(QueueKey, usize)> = BTreeSet::new();
    let mut admitted: BTreeSet<(QueueKey, usize)> = BTreeSet::new();
    let mut admitted_count: Vec<usize> = vec![0; cfg.n_replicas];

    let mut queue_depth: Vec<(Ms, usize)> = Vec::new();
    let mut clock: Ms = 0.0;
    let mut makespan: Ms = 0.0;
    // Max finish over emitted (completed/preempted) records: with
    // records streamed out at completion, the failure-time makespan
    // rebuild folds this instead of re-scanning finished sessions.
    let mut finalized_makespan: Ms = 0.0;
    let mut done = 0usize;
    let mut requeued = 0usize;
    let mut n_events: u64 = 0;
    let mut tick: u64 = 0;

    // Release the next request of `client`'s chain after a completion
    // (or rejection) at time `at`.
    let release_next = |events: &mut BinaryHeap<Reverse<Event>>,
                        chain_pos: &mut BTreeMap<u64, usize>,
                        client: u64,
                        at: Ms| {
        let chain = &chains[&client];
        let pos = chain_pos.get_mut(&client).expect("chain position");
        if *pos < chain.len() {
            let idx = chain[*pos];
            *pos += 1;
            let req = &requests[idx];
            let t = req.arrival_ms.max(at + req.think_ms);
            events.push(Reverse(Event { time: t, kind: EV_ARRIVAL, id: req.id, idx, epoch: 0 }));
        }
    };

    loop {
        // Drain every event due at `clock` in (time, kind, id) order —
        // the kind codes reproduce the round loop's completions →
        // failures → arrivals phase order. The first tick always runs
        // its phases (the round loop's unconditional first pass at
        // clock 0); after that, a drain of nothing but stale
        // completions runs none (see module docs).
        let mut acted = tick == 0;
        while let Some(&Reverse(ev)) = events.peek() {
            if ev.time > clock {
                break;
            }
            events.pop();
            n_events += 1;
            match ev.kind {
                EV_COMPLETION => {
                    let idx = ev.idx;
                    if arena.epoch[idx] != ev.epoch {
                        // Stale: the session re-queued when its replica
                        // died; its real completion is a future event.
                        continue;
                    }
                    acted = true;
                    debug_assert_eq!(arena.state[idx], SessState::Running, "completion state");
                    let ri = arena.owner[idx];
                    let r = &mut reps[ri];
                    let pos = r
                        .running
                        .iter()
                        .position(|&(i, _)| i == idx)
                        .expect("completed session in its replica's batch");
                    r.running.swap_remove(pos);
                    let bytes = arena.session_bytes[idx];
                    let freed = r.node.dealloc(bytes);
                    debug_assert_eq!(
                        freed,
                        bytes,
                        "memory ledger drift on request {}",
                        requests[idx].id
                    );
                    arena.state[idx] = SessState::Done;
                    done += 1;
                    let rec = arena.records[idx].take().expect("running session has a record");
                    finalized_makespan = finalized_makespan.max(rec.finish_ms);
                    sink.emit(rec);
                    if let Some(ctl) = &mut control {
                        ctl.epoch_completed += 1;
                    }
                    release_next(&mut events, &mut chain_pos, requests[idx].client, ev.time);
                }
                EV_FAILURE => {
                    acted = true;
                    let ri = ev.idx;
                    let r = &mut reps[ri];
                    debug_assert!(!r.dead, "one failure event per replica");
                    r.dead = true;
                    // Unfinished batch members re-queue with their
                    // ledger bytes released; eligibility (and thus
                    // policy key) is unchanged. The epoch bump strands
                    // their in-heap completion events.
                    let mut batch_end = clock;
                    for (idx, end) in r.running.drain(..) {
                        batch_end = batch_end.max(end);
                        r.node.dealloc(arena.session_bytes[idx]);
                        arena.records[idx] = None;
                        arena.epoch[idx] += 1;
                        arena.state[idx] = SessState::Waiting;
                        requeued += 1;
                        let key =
                            QueueKey::new(cfg.policy.key(&requests[idx], arena.eligible_at[idx]));
                        waiting.insert((key, idx));
                    }
                    // Busy only until it died: drop the aborted tail
                    // from utilization and bookings.
                    r.busy_ms -= (batch_end - clock).max(0.0);
                    if !lean {
                        r.bookings.retain(|&(_, end, _)| end <= clock);
                    }
                    // Admitted-but-queued sessions it owned re-queue too.
                    let mine: Vec<(QueueKey, usize)> = admitted
                        .iter()
                        .filter(|&&(_, idx)| arena.owner[idx] == ri)
                        .copied()
                        .collect();
                    for (key, idx) in mine {
                        admitted.remove(&(key, idx));
                        reps[ri].node.dealloc(arena.session_bytes[idx]);
                        arena.state[idx] = SessState::Waiting;
                        requeued += 1;
                        waiting.insert((key, idx));
                    }
                    admitted_count[ri] = 0;
                    // Aborted dispatches may have advanced the makespan
                    // past anything that will actually finish; rebuild
                    // from what survives — emitted finishes plus the
                    // other replicas' in-flight records.
                    makespan = finalized_makespan;
                    for rep in &reps {
                        for &(idx, _) in &rep.running {
                            if let Some(rec) = &arena.records[idx] {
                                makespan = makespan.max(rec.finish_ms);
                            }
                        }
                    }
                    if let Some(ctl) = &mut control {
                        // A failure shrinks the fleet the controller is
                        // paying for; advance the replica-ms integral.
                        ctl.note_live(clock, live_replicas(&reps));
                    }
                }
                EV_CONTROL => {
                    acted = true;
                    let ctl = control.as_mut().expect("control event without a controller");
                    // Fold the service model's accumulated expert-demand
                    // tallies (the batched path's load-dedup counts)
                    // into the cross-epoch popularity signal.
                    if let Some(d) = service.take_expert_demand() {
                        ctl.merge_demand(&d);
                    }
                    let live = live_replicas(&reps);
                    let busy = reps
                        .iter()
                        .filter(|r| !r.dead && !r.retired && !r.running.is_empty())
                        .count();
                    let obs = EpochObservation {
                        p99_ttft_ms: ctl.ttft.p(0.99),
                        queue_depth: waiting.len() + admitted.len(),
                        live_replicas: live,
                        busy_frac: if live > 0 { busy as f64 / live as f64 } else { 1.0 },
                        completed: std::mem::take(&mut ctl.epoch_completed),
                    };
                    let d = ctl.state.observe(&ctl.cfg, &obs);
                    let mut live_now = live;
                    if d.replica_delta > 0 && live < ctl.cfg.max_replicas {
                        // Un-retire the highest-index parked replica if
                        // one exists (its ledger is intact), else grow
                        // the fleet with a fresh node.
                        if let Some(ri) =
                            (0..reps.len()).rev().find(|&i| reps[i].retired && !reps[i].dead)
                        {
                            reps[ri].retired = false;
                        } else {
                            reps.push(EventReplica {
                                node: Node::new(reps.len()),
                                running: Vec::new(),
                                busy_ms: 0.0,
                                bookings: Vec::new(),
                                dead: false,
                                retired: false,
                            });
                            admitted_count.push(0);
                        }
                        ctl.report.scale_ups += 1;
                        live_now += 1;
                    } else if d.replica_delta < 0 && live > ctl.cfg.min_replicas {
                        // Retire the highest-index live replica. Its
                        // running batch drains; admitted-but-queued
                        // sessions migrate back to waiting with their
                        // ledger bytes released (counted as `migrated`,
                        // not `requeued` — nothing was aborted).
                        let ri = (0..reps.len())
                            .rev()
                            .find(|&i| !reps[i].dead && !reps[i].retired)
                            .expect("a live replica exists");
                        reps[ri].retired = true;
                        let mine: Vec<(QueueKey, usize)> = admitted
                            .iter()
                            .filter(|&&(_, idx)| arena.owner[idx] == ri)
                            .copied()
                            .collect();
                        for (key, idx) in mine {
                            admitted.remove(&(key, idx));
                            reps[ri].node.dealloc(arena.session_bytes[idx]);
                            arena.state[idx] = SessState::Waiting;
                            ctl.report.migrated += 1;
                            waiting.insert((key, idx));
                        }
                        admitted_count[ri] = 0;
                        ctl.report.scale_downs += 1;
                        live_now -= 1;
                    }
                    if d.tighten_admission {
                        ctl.admission_cap = Some(live_now * ctl.cfg.dispatch_width);
                        ctl.report.tightens += 1;
                    }
                    if d.relax {
                        ctl.admission_cap = None;
                        ctl.relief_scale = 1.0;
                    }
                    if d.precision_relief {
                        if ctl.relief_scale == 1.0 {
                            ctl.report.reliefs += 1;
                        }
                        ctl.relief_scale = ctl.cfg.relief_scale;
                    }
                    // One-shot popularity-driven replication: once the
                    // accumulated demand is skewed enough for a plan
                    // that lowers max load, place it and book its cost.
                    if ctl.report.replications == 0 && !ctl.demand.is_empty() {
                        let demand: Vec<usize> =
                            ctl.demand.iter().map(|&v| v as usize).collect();
                        if let Some(plan) = plan_replication(&ctl.cfg, &demand) {
                            ctl.replication_scale = plan.time_scale;
                            ctl.report.replications += 1;
                            ctl.report.replication_bytes =
                                plan.extra_replicas as u64 * ctl.cfg.expert_bytes;
                        }
                    }
                    ctl.note_live(clock, live_now);
                    ctl.report.epochs.push(EpochSnapshot {
                        t_ms: clock,
                        p99_ttft_ms: obs.p99_ttft_ms,
                        queue_depth: obs.queue_depth,
                        live_replicas: live_now,
                        completed: obs.completed,
                        action: d.label(),
                    });
                    control_due = Some(clock);
                }
                _ => {
                    acted = true;
                    let idx = ev.idx;
                    let t = ev.time;
                    arena.eligible_at[idx] = t;
                    let req = &requests[idx];
                    if arena.session_bytes[idx] > cfg.memory.budget_bytes {
                        // Can never fit any replica: rejected outright.
                        arena.state[idx] = SessState::Done;
                        done += 1;
                        sink.emit(SessionRecord {
                            id: req.id,
                            tenant: req.tenant,
                            replica: None,
                            arrival_ms: req.arrival_ms,
                            eligible_ms: t,
                            start_ms: t,
                            first_token_ms: None,
                            finish_ms: t,
                            tokens: Vec::new(),
                            requested_tokens: req.out_tokens,
                            stall_ms: 0.0,
                            slo: req.slo,
                            outcome: SessionOutcome::Rejected,
                        });
                        release_next(&mut events, &mut chain_pos, req.client, t);
                    } else {
                        arena.state[idx] = SessState::Waiting;
                        let key = QueueKey::new(cfg.policy.key(req, t));
                        waiting.insert((key, idx));
                    }
                }
            }
        }

        if acted {
            // Admission: waiting → replica ledgers, in key order, onto
            // the least-loaded live replica with room (ties prefer free
            // bytes, then the lowest index); stop at the first
            // head-of-line session that fits nowhere.
            while let Some(&(key, idx)) = waiting.first() {
                // Tightened admission: the controller caps in-flight
                // sessions (admitted + running) at live × width.
                if let Some(cap) = control.as_ref().and_then(|c| c.admission_cap) {
                    let in_flight =
                        admitted.len() + reps.iter().map(|r| r.running.len()).sum::<usize>();
                    if in_flight >= cap {
                        break;
                    }
                }
                let bytes = arena.session_bytes[idx];
                let mut best: Option<(usize, usize, u64)> = None;
                for (ri, r) in reps.iter().enumerate() {
                    if r.dead || r.retired {
                        continue;
                    }
                    let free = cfg.memory.budget_bytes.saturating_sub(r.node.gpu_bytes_used);
                    if free < bytes {
                        continue;
                    }
                    let load = admitted_count[ri] + r.running.len();
                    let better = match best {
                        None => true,
                        Some((_, bl, bf)) => load < bl || (load == bl && free > bf),
                    };
                    if better {
                        best = Some((ri, load, free));
                    }
                }
                let Some((ri, _, _)) = best else { break };
                reps[ri].node.alloc(bytes);
                admitted_count[ri] += 1;
                arena.owner[idx] = ri;
                arena.state[idx] = SessState::Admitted;
                admitted.insert((key, idx));
                waiting.remove(&(key, idx));
            }

            // Dispatch: each idle live replica starts up to `max_batch`
            // of the globally best admitted sessions as one batch,
            // stealing siblings' admitted sessions when they fit its
            // own ledger.
            for ri in 0..reps.len() {
                if reps[ri].dead || reps[ri].retired || !reps[ri].running.is_empty() {
                    continue;
                }
                let mut picked: Vec<usize> = Vec::new();
                while picked.len() < cfg.max_batch {
                    let free_ri =
                        cfg.memory.budget_bytes.saturating_sub(reps[ri].node.gpu_bytes_used);
                    // First in-order qualifying entry = the key-minimal
                    // one (keys embed the unique request id), i.e. the
                    // same choice the round loop's full scan made.
                    let choice = admitted
                        .iter()
                        .find(|&&(_, idx)| {
                            arena.owner[idx] == ri || arena.session_bytes[idx] <= free_ri
                        })
                        .copied();
                    let Some((key, idx)) = choice else { break };
                    admitted.remove(&(key, idx));
                    let qi = arena.owner[idx];
                    admitted_count[qi] -= 1;
                    if qi != ri {
                        let bytes = arena.session_bytes[idx];
                        let freed = reps[qi].node.dealloc(bytes);
                        debug_assert_eq!(freed, bytes, "steal ledger drift on request {idx}");
                        reps[ri].node.alloc(bytes);
                    }
                    picked.push(idx);
                }
                if picked.is_empty() {
                    continue;
                }
                let refs: Vec<&Request> = picked.iter().map(|&idx| &requests[idx]).collect();
                let mut profiles = service.measure_batch(&refs)?;
                ensure!(profiles.len() == picked.len(), "one profile per batched session");
                // Active relief / replication shrink service durations
                // at dispatch (never off: scale 1.0 means no-op and the
                // off path never builds a controller at all).
                if let Some(ctl) = &control {
                    let s = ctl.time_scale();
                    if s < 1.0 {
                        for p in &mut profiles {
                            p.ttft_ms *= s;
                            p.decode_ms *= s;
                            p.stall_ms *= s;
                        }
                    }
                }
                let start = clock;
                let mut batch_end = start;
                for (profile, &idx) in profiles.iter().zip(&picked) {
                    let req = &requests[idx];
                    let (kept, svc, preempted) = truncate(profile, cfg.preempt_budget_ms);
                    let finish = start + svc;
                    if let Some(ctl) = &mut control {
                        // Arrival → first token lands in the rolling
                        // window now, when the dispatch fixes it; tokens
                        // served under relief accrue quality debt.
                        if kept > 0 {
                            ctl.ttft.push(start + profile.ttft_ms - req.arrival_ms);
                        }
                        if ctl.relief_scale < 1.0 {
                            ctl.report.quality_debt_tokens += kept as u64;
                        }
                    }
                    arena.records[idx] = Some(SessionRecord {
                        id: req.id,
                        tenant: req.tenant,
                        replica: Some(ri),
                        arrival_ms: req.arrival_ms,
                        eligible_ms: arena.eligible_at[idx],
                        start_ms: start,
                        first_token_ms: (kept > 0).then_some(start + profile.ttft_ms),
                        finish_ms: finish,
                        tokens: profile.tokens[..kept].to_vec(),
                        requested_tokens: req.out_tokens,
                        stall_ms: profile.stall_ms,
                        slo: req.slo,
                        outcome: if preempted {
                            SessionOutcome::Preempted
                        } else {
                            SessionOutcome::Completed
                        },
                    });
                    arena.state[idx] = SessState::Running;
                    arena.owner[idx] = ri;
                    reps[ri].running.push((idx, finish));
                    if !lean {
                        reps[ri].bookings.push((start, finish, req.id));
                    }
                    events.push(Reverse(Event {
                        time: finish,
                        kind: EV_COMPLETION,
                        id: req.id,
                        idx,
                        epoch: arena.epoch[idx],
                    }));
                    batch_end = batch_end.max(finish);
                    makespan = makespan.max(finish);
                }
                reps[ri].busy_ms += batch_end - start;
            }

            // Queue-depth sample, every `stride` ticks, deduplicated.
            if tick % stride == 0 {
                let depth = waiting.len() + admitted.len();
                if queue_depth.last().map(|&(_, d)| d) != Some(depth) {
                    queue_depth.push((clock, depth));
                }
            }
            tick += 1;

            if done >= n {
                break;
            }

            // Re-arm the next controller epoch, but only while there is
            // work the controller could still affect: a running batch or
            // a pending non-control event. Otherwise the chain stops and
            // the empty-heap stall check below keeps its meaning.
            if let Some(epoch_t) = control_due.take() {
                let ctl = control.as_ref().expect("control_due without a controller");
                let work_left = reps.iter().any(|r| !r.running.is_empty())
                    || events.iter().any(|&Reverse(e)| e.kind != EV_CONTROL);
                if work_left {
                    events.push(Reverse(Event {
                        time: epoch_t + ctl.cfg.epoch_ms,
                        kind: EV_CONTROL,
                        id: 0,
                        idx: 0,
                        epoch: 0,
                    }));
                }
            }
        }

        // Advance to the next pending event. An empty heap with work
        // outstanding means failures killed every replica that could
        // serve the remaining queue (running sessions always hold a
        // live completion event, live failing replicas a failure event).
        match events.peek() {
            Some(&Reverse(ev)) => clock = ev.time,
            None => bail!(
                "scheduler stalled with {} request(s) stuck waiting ({} of {} replica(s) dead)",
                waiting.len(),
                reps.iter().filter(|r| r.dead).count(),
                reps.len()
            ),
        }
    }

    Ok(CoreOutcome {
        makespan_ms: makespan,
        queue_depth,
        replica_busy_ms: reps.iter().map(|r| r.busy_ms).collect(),
        bookings: reps.into_iter().map(|r| r.bookings).collect(),
        requeued,
        events: n_events,
        ticks: tick,
        arena_bytes,
        control: control.map(|ctl| ctl.finalize(makespan.max(clock))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::{Policy, Scheduler, SyntheticService};

    /// The retired comparator, verbatim: descending (time, id) sort so
    /// `pop()` from the Vec tail yields the earliest entry.
    fn oracle_insert(v: &mut Vec<(Ms, u64, usize)>, e: (Ms, u64, usize)) {
        let at = v.partition_point(|x| x.0 > e.0 || (x.0 == e.0 && x.1 > e.1));
        v.insert(at, e);
    }

    #[test]
    fn futureheap_pops_in_old_comparator_order() {
        // Satellite pin: the heap must pop exactly as the old sorted-Vec
        // + insert_future pair did, including (time) ties broken by id.
        let entries: Vec<(Ms, u64, usize)> = vec![
            (5.0, 3, 0),
            (1.0, 9, 1),
            (5.0, 1, 2),
            (0.0, 4, 3),
            (2.5, 7, 4),
            (2.5, 2, 5),
            (1.0, 0, 6),
            (7.25, 5, 7),
        ];
        let mut oracle: Vec<(Ms, u64, usize)> = Vec::new();
        let mut heap = FutureHeap::with_capacity(entries.len());
        for &e in &entries {
            oracle_insert(&mut oracle, e);
            heap.push(e);
        }
        while let Some(expect) = oracle.pop() {
            assert_eq!(heap.peek(), Some(expect));
            assert_eq!(heap.pop(), Some(expect));
        }
        assert_eq!(heap.pop(), None);
    }

    #[test]
    fn event_order_is_time_then_kind_then_id() {
        let ev = |time, kind, id| Event { time, kind, id, idx: 0, epoch: 0 };
        let mut h: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        for e in [
            ev(1.0, EV_ARRIVAL, 0),
            ev(1.0, EV_COMPLETION, 5),
            ev(0.5, EV_ARRIVAL, 9),
            ev(1.0, EV_FAILURE, 1),
            ev(1.0, EV_COMPLETION, 2),
        ] {
            h.push(Reverse(e));
        }
        let popped: Vec<(Ms, u8, u64)> = std::iter::from_fn(|| h.pop())
            .map(|Reverse(e)| (e.time, e.kind, e.id))
            .collect();
        // Same time: completions (id ascending), then failures, then
        // arrivals — the round loop's phase order.
        assert_eq!(
            popped,
            vec![
                (0.5, EV_ARRIVAL, 9),
                (1.0, EV_COMPLETION, 2),
                (1.0, EV_COMPLETION, 5),
                (1.0, EV_FAILURE, 1),
                (1.0, EV_ARRIVAL, 0),
            ]
        );
    }

    #[test]
    fn streamed_run_matches_collected_outcome() {
        // run_streamed must make the same scheduling decisions as run —
        // only retention differs.
        let reqs: Vec<Request> = (0..40)
            .map(|i| {
                let mut r = Request::open_loop(i, vec![1 + i as u32], 6, i as f64 * 3.0);
                r.client = i % 5; // 5 chains of 8
                r.think_ms = 2.0;
                r
            })
            .collect();
        let cfg = SchedulerConfig {
            n_replicas: 2,
            max_batch: 2,
            policy: Policy::Sjf,
            ..SchedulerConfig::default()
        };
        let mut svc = SyntheticService::new(2.0, 0.1, 1.0).with_batch_marginal(0.3);
        let out = Scheduler::run(&cfg, &mut svc.clone(), &reqs).unwrap();
        let stats = run_streamed(&cfg, &mut svc, &reqs, 1 << 12).unwrap();
        assert_eq!(stats.completed as usize + stats.preempted as usize, out.records.len());
        assert_eq!(stats.makespan_ms, out.makespan_ms);
        assert_eq!(stats.requeued, out.requeued);
        assert_eq!(
            stats.total_tokens,
            out.records.iter().map(|r| r.tokens.len() as u64).sum::<u64>()
        );
        let mut e2e = stats.e2e.clone();
        let s = e2e.summary();
        assert!(s.count == out.records.len() && stats.e2e.is_exact());
        assert!(stats.events > 0 && stats.ticks > 0 && stats.arena_bytes > 0);
    }

    #[test]
    fn a_controller_that_never_acts_leaves_records_and_makespan_identical() {
        // A reactive controller whose thresholds can never trip (huge
        // target, huge dispatch width, fleet pinned min == max) adds
        // control events — extra clock stops and queue samples — but
        // must not move a single token or timing: replicas and ledger
        // bytes only free at completions, so the extra admission and
        // dispatch passes at quiescent instants are provable no-ops.
        use crate::serve::WorkloadSpec;
        let reqs = WorkloadSpec::poisson(6.0, 30, 256).generate(11);
        let base = SchedulerConfig { n_replicas: 2, max_batch: 2, ..SchedulerConfig::default() };
        let controlled = SchedulerConfig {
            control: Some(ControlConfig {
                epoch_ms: 50.0,
                target_p99_ttft_ms: 1e9,
                min_replicas: 2,
                max_replicas: 2,
                dispatch_width: 1 << 20,
                ..ControlConfig::default()
            }),
            ..base.clone()
        };
        let mut svc = SyntheticService::new(4.0, 0.1, 2.0).with_batch_marginal(0.4);
        let off = run(&base, &mut svc.clone(), &reqs).unwrap();
        let on = run(&controlled, &mut svc, &reqs).unwrap();
        assert_eq!(format!("{:?}", off.records), format!("{:?}", on.records));
        assert_eq!(off.makespan_ms, on.makespan_ms);
        assert!(off.control.is_none(), "off runs carry no report");
        let report = on.control.expect("reactive runs carry a report");
        assert_eq!((report.scale_ups, report.scale_downs, report.reliefs), (0, 0, 0));
        assert_eq!(report.replications, 0, "synthetic service reports no expert demand");
        assert!(report.epochs.iter().all(|e| e.action == "relax" || e.action == "hold"));
        assert!(!report.epochs.is_empty() && report.replica_ms > 0.0);
        assert_eq!((report.peak_replicas, report.final_replicas), (2, 2));
    }

    #[test]
    fn sustained_pressure_scales_up_and_elasticity_beats_the_static_fleet() {
        // Arrivals far outpace a single replica: the queue blows past
        // 2 x live x width before the first epoch, so the controller
        // must add replicas — and the report must price them. On this
        // embarrassingly parallel backlog a 4-replica peak finishes
        // strictly sooner than the static single replica.
        let reqs: Vec<Request> =
            (0..40).map(|i| Request::open_loop(i, vec![1], 4, i as f64)).collect();
        let cfg = SchedulerConfig {
            n_replicas: 1,
            max_batch: 2,
            control: Some(ControlConfig {
                epoch_ms: 40.0,
                min_replicas: 1,
                max_replicas: 4,
                dispatch_width: 2,
                ..ControlConfig::default()
            }),
            ..SchedulerConfig::default()
        };
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0);
        let out = run(&cfg, &mut svc, &reqs).unwrap();
        let report = out.control.expect("reactive run reports");
        assert!(report.scale_ups >= 1, "{report:?}");
        assert!(report.peak_replicas > 1 && report.replica_ms > 0.0);
        assert!(report.epochs.iter().any(|e| e.action == "scale-up"));
        let static_cfg = SchedulerConfig { control: None, ..cfg.clone() };
        let mut svc = SyntheticService::new(10.0, 0.0, 10.0);
        let static_out = run(&static_cfg, &mut svc, &reqs).unwrap();
        assert!(
            out.makespan_ms < static_out.makespan_ms,
            "reactive {} !< static {}",
            out.makespan_ms,
            static_out.makespan_ms
        );
        assert_eq!(out.records.len(), static_out.records.len(), "same sessions served");
    }

    #[test]
    fn retirement_drains_cleanly_and_every_session_completes() {
        // Force a calm fleet of 3 down: retirements park replicas
        // (running batches drain, admitted sessions migrate with their
        // ledger bytes) and the run must still complete every session
        // without a single abort-requeue.
        let reqs: Vec<Request> =
            (0..12).map(|i| Request::open_loop(i, vec![1], 2, i as f64 * 60.0)).collect();
        let cfg = SchedulerConfig {
            n_replicas: 3,
            max_batch: 1,
            control: Some(ControlConfig {
                epoch_ms: 30.0,
                target_p99_ttft_ms: 1e9,
                min_replicas: 1,
                max_replicas: 3,
                dispatch_width: 4,
                ..ControlConfig::default()
            }),
            ..SchedulerConfig::default()
        };
        // Short sessions, long gaps: the fleet idles between arrivals,
        // so calm epochs accumulate and the controller sheds replicas.
        let mut svc = SyntheticService::new(2.0, 0.0, 1.0);
        let out = run(&cfg, &mut svc, &reqs).unwrap();
        let report = out.control.expect("reactive run reports");
        assert!(report.scale_downs >= 1, "{report:?}");
        assert!(report.final_replicas < 3);
        assert_eq!(out.records.len(), reqs.len(), "every session still completes");
        assert_eq!(out.requeued, 0, "migration is not an abort");
    }

    #[test]
    fn arena_footprint_is_linear_in_sessions() {
        let mk = |n: usize| {
            let reqs: Vec<Request> =
                (0..n as u64).map(|i| Request::open_loop(i, vec![1], 4, 0.0)).collect();
            SessionArena::new(&SchedulerConfig::default(), &reqs).footprint_bytes()
        };
        let (small, big) = (mk(100), mk(1000));
        assert!(big >= 9 * small && big <= 11 * small, "{small} vs {big}");
    }
}
