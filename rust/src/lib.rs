//! # OD-MoE — On-Demand Expert Loading for Cacheless Edge-Distributed MoE Inference
//!
//! Reproduction of the CS.DC 2025 paper as a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build-time Python)** — Tiny-Mixtral compute graphs with
//!   Pallas kernels for the hot spots, AOT-lowered to HLO text under
//!   `artifacts/` by `make artifacts`.
//! * **Layer 3 (this crate)** — the coordinator: PJRT runtime, virtual-time
//!   edge-cluster simulator, SEP shadow-model predictor with token/KV
//!   alignment, worker grouping + round-robin decode pipeline, prefill
//!   mini-batching, and the full set of baseline engines and predictors
//!   the paper benchmarks against.
//!
//! Quick tour:
//! * [`runtime::Runtime`] — loads + executes the AOT artifacts on the PJRT
//!   CPU client (Python never runs on the request path).
//! * [`engine::ModelState`] — full-model forward (prefill + decode) over the
//!   runtime; used both by the full-precision main node and the quantized
//!   shadow node.
//! * [`coordinator::OdMoeEngine`] — the paper's system: cacheless on-demand
//!   expert loading driven by [`predictor::SepPredictor`].
//! * [`coordinator::baselines`] — Mixtral-Offloading / MoE-Infinity /
//!   HOBBIT / AdapMoE / fully-cached / CPU-only reference engines.
//! * [`workload`] — prompt corpora and the speed/quality harnesses that
//!   regenerate every table and figure of the paper's evaluation.
//! * [`serve`] — the multi-tenant load-test layer: seeded arrival traces
//!   (Poisson / bursty / replayed / closed-loop), a continuous
//!   virtual-time scheduler over engine-replica pools with FCFS/SJF/EDF
//!   policies, ledger-backed admission control, over-budget preemption
//!   and multi-session batched dispatch, SLO metrics (exact p50/p95/p99
//!   TTFT, goodput), and the sweep harnesses behind `BENCH_serve.json`
//!   and `BENCH_batch.json`.
//! * [`coordinator::BatchEngine`] — multi-session batched decode: N
//!   sessions step through one decode iteration together with merged
//!   routes, so one expert load serves every session that routed to it
//!   (DESIGN.md §7).
//! * [`fleet`] — heterogeneous node classes ([`cluster::NodeClass`],
//!   `FleetSpec` compositions like `rtx3080:4,jetson:4,nano:2`) threaded
//!   through the cluster so each worker books its own class's durations,
//!   plus the SLO-driven deployment planner behind `BENCH_plan.json`
//!   and `od-moe serve --plan` (DESIGN.md §10).
//! * [`telemetry`] — observability: per-token critical-path attribution
//!   over traces (`od-moe decode --attribution`, `BENCH_attrib.json`), a
//!   unified metrics registry with one JSONL export schema, and the
//!   `od-moe bench` perf-regression gate (DESIGN.md §11).
//! * [`control`] — the online SLO control loop: rolling-window
//!   observations feed a deterministic decision engine that scales
//!   replicas, tightens admission, downgrades transfer precision and
//!   replicates hot experts live between epochs (`--control reactive`,
//!   `od-moe serve --autoscale-sweep`, DESIGN.md §15).

pub mod cache;
pub mod cluster;
pub mod control;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod predictor;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod workload;

pub use model::config::ModelConfig;
pub use runtime::Runtime;
