//! SLO-driven deployment planner (DESIGN.md §10).
//!
//! A candidate deployment is (class subset, transfer precision, chunk
//! count, prefetch depth, replica count) over a [`FleetSpec`]. The
//! planner prunes analytically — every included class must hold the
//! per-class Eq. (1) no-stall window at the candidate's precision and
//! chunking, and steady expert residency must fit each class's memory
//! budget — then scores the survivors with a caller-supplied evaluator
//! (the CLI wires [`crate::coordinator::OdMoeEngine`] through the
//! serving scheduler in virtual time; tests wire a closed form). The
//! output is a deterministic Pareto frontier over (p99 TPOT, total GPU
//! bytes, node-class bill) and a chosen plan — the cheapest candidate
//! meeting the target SLO — emitted as `BENCH_plan.json`, which
//! `od-moe serve --plan` re-runs directly.
//!
//! Everything here is pure bookkeeping over the measurements: same seed,
//! same fleet, same grid → byte-identical JSON (CI diffs two runs).

use anyhow::{ensure, Context, Result};

use super::FleetSpec;
use crate::cluster::HardwareProfile;
use crate::coordinator::precision::PrecisionPolicy;
use crate::quant::Precision;
use crate::util::json::Json;

/// The planner's search grid. Defaults cover the knobs the last PRs
/// built: precision (HOBBIT's lever), chunked streaming, speculative
/// prefetch, replica count, cache budget, and the runtime precision
/// policy (DESIGN.md §14).
#[derive(Debug, Clone)]
pub struct PlanGrid {
    pub precisions: Vec<Precision>,
    pub chunk_counts: Vec<usize>,
    pub depths: Vec<usize>,
    pub replicas: Vec<usize>,
    /// Per-worker GPU-hot tier budgets (expert slots) to consider;
    /// 0 = cacheless, the seed behavior (DESIGN.md §12).
    pub cache_budgets: Vec<usize>,
    /// Runtime precision policies to consider (DESIGN.md §14);
    /// [`PrecisionPolicy::Static`] = the deployed precision for every
    /// load, the seed behavior.
    pub policies: Vec<PrecisionPolicy>,
}

impl Default for PlanGrid {
    fn default() -> Self {
        Self {
            precisions: vec![Precision::Fp16, Precision::Int8, Precision::Nf4],
            chunk_counts: vec![1, 8],
            depths: vec![0, 1],
            replicas: vec![1],
            cache_budgets: vec![0],
            policies: vec![PrecisionPolicy::Static],
        }
    }
}

impl PlanGrid {
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.precisions.is_empty(), "grid needs at least one precision");
        ensure!(
            self.chunk_counts.iter().all(|&c| c >= 1) && !self.chunk_counts.is_empty(),
            "chunk counts must be >= 1"
        );
        ensure!(!self.depths.is_empty(), "grid needs at least one prefetch depth");
        ensure!(
            self.replicas.iter().all(|&r| r >= 1) && !self.replicas.is_empty(),
            "replica counts must be >= 1"
        );
        ensure!(!self.cache_budgets.is_empty(), "grid needs at least one cache budget (0 = off)");
        ensure!(
            !self.policies.is_empty(),
            "grid needs at least one precision policy (static = off)"
        );
        Ok(())
    }
}

/// One point of the search space: a runnable deployment configuration.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    /// The sub-fleet whose nodes serve expert slots.
    pub fleet: FleetSpec,
    /// In-flight expert transfer precision (scales
    /// [`HardwareProfile::expert_bytes`] by
    /// [`Precision::transfer_factor`]; numerics stay FP32).
    pub precision: Precision,
    pub chunks: usize,
    pub prefetch_depth: usize,
    pub replicas: usize,
    /// Per-worker GPU-hot cache budget in expert slots (0 = cacheless).
    pub cache_hot: usize,
    /// Runtime per-load precision policy (DESIGN.md §14). Non-static
    /// policies may downgrade individual transfers below the deployed
    /// `precision` when the Eq. (1) slack is short, so their window
    /// feasibility is judged at the best-case (NF4) stream size.
    pub policy: PrecisionPolicy,
}

/// `base` with an in-flight transfer precision applied: `expert_bytes`
/// scaled by [`Precision::transfer_factor`] (numerics stay FP32 — the
/// stream shrinks, nothing else). The single constructor behind plan
/// candidates, plan re-runs (`--plan`), and the `memory --fleet` audit,
/// so the three surfaces cannot scale differently.
pub fn precision_scaled(base: &HardwareProfile, precision: Precision) -> HardwareProfile {
    HardwareProfile {
        expert_bytes: base.expert_bytes * precision.transfer_factor(),
        ..base.clone()
    }
}

impl PlanCandidate {
    /// Human-readable candidate id, also the deterministic tie-breaker.
    /// Cacheless candidates keep the pre-cache label so old plan files
    /// and new ones name the same deployment the same way.
    pub fn label(&self) -> String {
        let base = format!(
            "{}/{}/c{}/d{}/r{}",
            self.fleet.label(),
            self.precision.label(),
            self.chunks,
            self.prefetch_depth,
            self.replicas
        );
        let base = if self.cache_hot > 0 {
            format!("{base}/h{}", self.cache_hot)
        } else {
            base
        };
        if self.policy == PrecisionPolicy::Static {
            base
        } else {
            format!("{base}/{}", self.policy.label())
        }
    }

    /// The base profile with this candidate's transfer precision applied.
    pub fn scaled_profile(&self, base: &HardwareProfile) -> HardwareProfile {
        precision_scaled(base, self.precision)
    }
}

/// What the evaluator measured for one candidate, all in virtual time.
#[derive(Debug, Clone)]
pub struct PlanMeasurement {
    /// Mean decode ms per token across served sessions.
    pub ms_per_token: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p99_ms: f64,
    /// Fraction of requests meeting the workload's SLO.
    pub slo_attainment: f64,
    /// Ledger peaks at paper scale (the `metrics::memory` ground truth).
    pub main_peak_bytes: f64,
    pub shadow_peak_bytes: f64,
    /// One entry per worker, worker-id order.
    pub worker_peak_bytes: Vec<f64>,
}

/// A measured candidate with its derived verdicts.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub candidate: PlanCandidate,
    pub meas: PlanMeasurement,
    /// Σ ledger peaks across main + shadow + workers, × replicas.
    pub total_gpu_bytes: f64,
    /// Node-class bill: Σ count × unit cost × replicas.
    pub cost: f64,
    /// Every worker's ledger peak within its class's memory budget.
    pub mem_ok: bool,
    /// Ledger peaks also within the analytic `metrics::memory` fleet
    /// audit bound — the cross-check that the audit formula and the
    /// engine's byte ledger agree.
    pub ledger_within_audit: bool,
    pub meets_slo: bool,
    /// On the (tpot p99, total bytes, cost) Pareto frontier among
    /// mem-feasible points.
    pub pareto: bool,
}

/// Everything one planner run produced.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub slo_p99_tpot_ms: f64,
    /// Measured points, in deterministic search order.
    pub points: Vec<PlanPoint>,
    /// Candidates removed by the analytic window/memory prefilter.
    pub pruned: usize,
    /// Index into `points` of the chosen plan (cheapest SLO-meeting,
    /// memory-feasible candidate), if any qualifies.
    pub chosen: Option<usize>,
}

impl PlanReport {
    pub fn chosen_point(&self) -> Option<&PlanPoint> {
        self.chosen.map(|i| &self.points[i])
    }
}

/// Exhaustive deterministic search. `eval` measures one candidate (the
/// CLI runs the real engine through the scheduler; tests use a closed
/// form); it is called only for candidates that survive the analytic
/// prefilter, in a fixed order (subset mask ascending, then grid order),
/// so the emitted JSON is byte-stable for a given seed. `max_batch` is
/// the serving batch limit the deployment will run with — it sizes the
/// memory bound a worker must fit.
///
/// Candidate scoring is inherently serial — `eval` is `FnMut`, because
/// the CLI's closure borrows one measuring engine/runtime mutably — so
/// `--threads` does not apply here (unlike the runtime-free serve
/// sweeps, whose cells fan out via `crate::serve::harness::parallel_map`).
/// Measurements made through `crate::serve::Scheduler::run` inherit the
/// event core (DESIGN.md §13), bit-identical to the old round loop.
#[allow(clippy::too_many_arguments)]
pub fn search(
    fleet: &FleetSpec,
    base: &HardwareProfile,
    group_size: usize,
    max_batch: usize,
    slo_p99_tpot_ms: f64,
    grid: &PlanGrid,
    mut eval: impl FnMut(&PlanCandidate) -> Result<PlanMeasurement>,
) -> Result<PlanReport> {
    ensure!(group_size >= 1, "need a positive group size");
    ensure!(max_batch >= 1, "need a positive batch limit");
    ensure!(
        slo_p99_tpot_ms.is_finite() && slo_p99_tpot_ms > 0.0,
        "SLO target must be finite and positive, got {slo_p99_tpot_ms}"
    );
    grid.validate()?;
    fleet.validate(base)?;

    let n_entries = fleet.entries().len();
    ensure!(n_entries <= 8, "planner supports up to 8 node classes, got {n_entries}");
    let mut points: Vec<PlanPoint> = Vec::new();
    let mut pruned = 0usize;

    for mask in 1usize..(1 << n_entries) {
        let Some(sub) = fleet.subset(mask) else { continue };
        if sub.n_nodes() < group_size {
            pruned += 1;
            continue;
        }
        let n_groups = sub.n_nodes() / group_size;
        for &precision in &grid.precisions {
            for &chunks in &grid.chunk_counts {
                for &prefetch_depth in &grid.depths {
                    for &replicas in &grid.replicas {
                        for &cache_hot in &grid.cache_budgets {
                            for &policy in &grid.policies {
                                let cand = PlanCandidate {
                                    fleet: sub.clone(),
                                    precision,
                                    chunks,
                                    prefetch_depth,
                                    replicas,
                                    cache_hot,
                                    policy,
                                };
                                let scaled = cand.scaled_profile(base);
                                // Window prefilter: every included class must
                                // hold one slot inside its own Eq. (1) window
                                // (the subset without an incapable class is its
                                // own candidate, so pruning loses nothing).
                                // A runtime policy may downgrade any transfer
                                // down to NF4 of the deployed stream, so its
                                // feasibility is judged at that best case —
                                // the evaluator then measures what the policy
                                // actually achieves.
                                let window_profile = if policy == PrecisionPolicy::Static {
                                    scaled.clone()
                                } else {
                                    precision_scaled(&scaled, Precision::Nf4)
                                };
                                let window_ok = sub.entries().iter().all(|(c, _)| {
                                    c.worker_profile(&window_profile)
                                        .reroute_feasible(1, n_groups, chunks)
                                });
                                // Memory prefilter: steady residency (depth + 1
                                // staged experts + the GPU-hot cache budget +
                                // workspace) within each class's budget. Buffers
                                // are provisioned at the deployed precision even
                                // under a runtime policy (downgrades shrink the
                                // wire stream, not the resident copy).
                                let mem_floor_ok = sub.entries().iter().all(|(c, _)| {
                                    (prefetch_depth + 1 + cache_hot) as f64 * scaled.expert_bytes
                                        + scaled.activation_bytes
                                        <= c.mem_bytes
                                });
                                if !window_ok || !mem_floor_ok {
                                    pruned += 1;
                                    continue;
                                }
                                let meas = eval(&cand).with_context(|| {
                                    format!("evaluating plan {}", cand.label())
                                })?;
                                ensure!(
                                    meas.worker_peak_bytes.len() == sub.n_nodes(),
                                    "{}: one worker peak per node ({} vs {})",
                                    cand.label(),
                                    meas.worker_peak_bytes.len(),
                                    sub.n_nodes()
                                );
                                let classes = sub.node_classes();
                                let mem_ok = classes
                                    .iter()
                                    .zip(&meas.worker_peak_bytes)
                                    .all(|(c, &peak)| peak <= c.mem_bytes);
                                let bound = crate::metrics::memory::fleet_worker_bound_bytes(
                                    &scaled,
                                    group_size,
                                    max_batch,
                                    prefetch_depth,
                                    cache_hot,
                                );
                                let ledger_within_audit = meas
                                    .worker_peak_bytes
                                    .iter()
                                    .all(|&peak| peak <= bound + 0.5);
                                let total_gpu_bytes = (meas.main_peak_bytes
                                    + meas.shadow_peak_bytes
                                    + meas.worker_peak_bytes.iter().sum::<f64>())
                                    * replicas as f64;
                                let cost = sub.bill() * replicas as f64;
                                let meets_slo = meas.tpot_p99_ms <= slo_p99_tpot_ms;
                                points.push(PlanPoint {
                                    candidate: cand,
                                    meas,
                                    total_gpu_bytes,
                                    cost,
                                    mem_ok,
                                    ledger_within_audit,
                                    meets_slo,
                                    pareto: false,
                                });
                            }
                        }
                    }
                }
            }
        }
    }

    // Pareto frontier over (tpot p99 ↓, total bytes ↓, cost ↓) among
    // memory-feasible points.
    let key = |p: &PlanPoint| (p.meas.tpot_p99_ms, p.total_gpu_bytes, p.cost);
    for i in 0..points.len() {
        if !points[i].mem_ok {
            continue;
        }
        let (t, b, c) = key(&points[i]);
        let dominated = points.iter().enumerate().any(|(j, q)| {
            if i == j || !q.mem_ok {
                return false;
            }
            let (t2, b2, c2) = key(q);
            t2 <= t && b2 <= b && c2 <= c && (t2 < t || b2 < b || c2 < c)
        });
        points[i].pareto = !dominated;
    }

    // Chosen plan: cheapest memory-feasible candidate meeting the SLO;
    // ties break on p99, then ms/token, then the candidate label.
    let chosen = points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.mem_ok && p.meets_slo)
        .min_by(|(_, a), (_, b)| {
            let f = |x: f64, y: f64| x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal);
            f(a.cost, b.cost)
                .then(f(a.meas.tpot_p99_ms, b.meas.tpot_p99_ms))
                .then(f(a.meas.ms_per_token, b.meas.ms_per_token))
                .then(a.candidate.label().cmp(&b.candidate.label()))
        })
        .map(|(i, _)| i);

    Ok(PlanReport { slo_p99_tpot_ms, points, pruned, chosen })
}

/// The deployed value plus its nearest grid neighbor on either side —
/// at most three values, whatever the grid size. The deployed value is
/// always included even when it is not a grid point, so a plan that
/// drifted off the grid can still step back toward it.
fn neighborhood(grid: &[usize], current: usize) -> Vec<usize> {
    let mut vals: Vec<usize> = grid.to_vec();
    vals.push(current);
    vals.sort_unstable();
    vals.dedup();
    let i = vals.iter().position(|&v| v == current).expect("current value was just inserted");
    let lo = i.saturating_sub(1);
    let hi = (i + 1).min(vals.len() - 1);
    vals[lo..=hi].to_vec()
}

impl PlanGrid {
    /// This grid narrowed to the neighborhood of a deployed plan: each
    /// numeric dimension keeps only the deployed value and its nearest
    /// grid neighbors ([`neighborhood`]); precisions and runtime
    /// policies stay as-is (both lists are three entries at most). The
    /// result bounds the candidate count by a constant independent of
    /// the full grid's size.
    pub fn narrowed_around(&self, current: &PlanChoice) -> PlanGrid {
        PlanGrid {
            precisions: self.precisions.clone(),
            chunk_counts: neighborhood(&self.chunk_counts, current.chunks),
            depths: neighborhood(&self.depths, current.prefetch_depth),
            replicas: neighborhood(&self.replicas, current.replicas),
            cache_budgets: neighborhood(&self.cache_budgets, current.cache_hot),
            policies: self.policies.clone(),
        }
    }
}

/// Bounded live replan (DESIGN.md §15): [`search`] restricted to the
/// neighborhood of the currently deployed plan instead of the full
/// grid. The SLO control loop re-searches between epochs, where an
/// exhaustive sweep would not fit in one epoch; narrowing every numeric
/// dimension to at most three values caps the candidate count at a
/// constant, and because this reuses `search` verbatim the report,
/// prefilter, Pareto, and chosen-plan semantics are identical to the
/// offline planner's.
#[allow(clippy::too_many_arguments)]
pub fn replan(
    fleet: &FleetSpec,
    base: &HardwareProfile,
    group_size: usize,
    max_batch: usize,
    slo_p99_tpot_ms: f64,
    grid: &PlanGrid,
    current: &PlanChoice,
    eval: impl FnMut(&PlanCandidate) -> Result<PlanMeasurement>,
) -> Result<PlanReport> {
    let narrowed = grid.narrowed_around(current);
    search(fleet, base, group_size, max_batch, slo_p99_tpot_ms, &narrowed, eval)
}

fn candidate_json(c: &PlanCandidate) -> Vec<(&'static str, Json)> {
    vec![
        ("fleet", Json::Str(c.fleet.label())),
        ("precision", Json::Str(c.precision.label().to_string())),
        ("chunks", Json::Num(c.chunks as f64)),
        ("prefetch_depth", Json::Num(c.prefetch_depth as f64)),
        ("replicas", Json::Num(c.replicas as f64)),
        ("cache_hot", Json::Num(c.cache_hot as f64)),
        ("policy", Json::Str(c.policy.label().to_string())),
    ]
}

fn num(v: f64) -> Json {
    // Unlike util::json::num (which only rounds), this keeps NaN/inf
    // out of the artifact: an unmeasurable metric serializes as null.
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Assemble the `BENCH_plan.json` document.
pub fn plan_json(report: &PlanReport, fleet: &FleetSpec, grid: &PlanGrid, seed: u64) -> Json {
    let obj = |pairs: Vec<(&str, Json)>| {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    let grid_json = obj(vec![
        (
            "precisions",
            Json::Arr(grid.precisions.iter().map(|p| Json::Str(p.label().to_string())).collect()),
        ),
        (
            "chunk_counts",
            Json::Arr(grid.chunk_counts.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        ("depths", Json::Arr(grid.depths.iter().map(|&d| Json::Num(d as f64)).collect())),
        ("replicas", Json::Arr(grid.replicas.iter().map(|&r| Json::Num(r as f64)).collect())),
        (
            "cache_budgets",
            Json::Arr(grid.cache_budgets.iter().map(|&h| Json::Num(h as f64)).collect()),
        ),
        (
            "policies",
            Json::Arr(grid.policies.iter().map(|p| Json::Str(p.label().to_string())).collect()),
        ),
    ]);
    let points = Json::Arr(
        report
            .points
            .iter()
            .map(|p| {
                let mut pairs = candidate_json(&p.candidate);
                pairs.push(("ms_per_token", num(p.meas.ms_per_token)));
                pairs.push(("ttft_p99_ms", num(p.meas.ttft_p99_ms)));
                pairs.push(("tpot_p99_ms", num(p.meas.tpot_p99_ms)));
                pairs.push(("slo_attainment", num(p.meas.slo_attainment)));
                pairs.push(("total_gpu_bytes", num(p.total_gpu_bytes)));
                pairs.push(("cost", num(p.cost)));
                pairs.push(("mem_ok", Json::Bool(p.mem_ok)));
                pairs.push(("ledger_within_audit", Json::Bool(p.ledger_within_audit)));
                pairs.push(("meets_slo", Json::Bool(p.meets_slo)));
                pairs.push(("pareto", Json::Bool(p.pareto)));
                pairs.push((
                    "worker_peak_bytes",
                    Json::Arr(p.meas.worker_peak_bytes.iter().map(|&b| num(b)).collect()),
                ));
                obj(pairs)
            })
            .collect(),
    );
    let chosen = match report.chosen_point() {
        Some(p) => {
            let mut pairs = candidate_json(&p.candidate);
            pairs.push(("tpot_p99_ms", num(p.meas.tpot_p99_ms)));
            pairs.push(("ms_per_token", num(p.meas.ms_per_token)));
            pairs.push(("cost", num(p.cost)));
            obj(pairs)
        }
        None => Json::Null,
    };
    obj(vec![
        ("bench", Json::Str("plan".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("fleet", Json::Str(fleet.label())),
        ("slo_p99_tpot_ms", num(report.slo_p99_tpot_ms)),
        ("grid", grid_json),
        ("pruned", Json::Num(report.pruned as f64)),
        ("points", points),
        ("chosen", chosen),
    ])
}

/// A chosen plan read back from `BENCH_plan.json` — what
/// `od-moe serve --plan` / `decode --plan` runs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanChoice {
    pub fleet: FleetSpec,
    pub precision: Precision,
    pub chunks: usize,
    pub prefetch_depth: usize,
    pub replicas: usize,
    /// Per-worker GPU-hot cache budget; plan files written before the
    /// tiered cache existed read back as 0 (cacheless).
    pub cache_hot: usize,
    /// Runtime precision policy; plan files written before the policy
    /// dimension existed read back as [`PrecisionPolicy::Static`].
    pub policy: PrecisionPolicy,
    /// The p99 TPOT the plan claimed when it was chosen (re-simulation
    /// should reproduce it — virtual time is deterministic).
    pub claimed_tpot_p99_ms: f64,
}

impl PlanChoice {
    pub fn from_json(doc: &Json) -> Result<Self> {
        let chosen = doc.get("chosen")?;
        ensure!(
            !matches!(chosen, Json::Null),
            "plan file chose no deployment (no candidate met the SLO within budget)"
        );
        Ok(Self {
            fleet: FleetSpec::parse(chosen.get("fleet")?.as_str()?)?,
            precision: Precision::parse(chosen.get("precision")?.as_str()?)?,
            chunks: chosen.get("chunks")?.as_usize()?,
            prefetch_depth: chosen.get("prefetch_depth")?.as_usize()?,
            replicas: chosen.get("replicas")?.as_usize()?,
            cache_hot: match chosen.get("cache_hot") {
                Ok(v) => v.as_usize()?,
                Err(_) => 0, // pre-cache plan file
            },
            policy: match chosen.get("policy") {
                Ok(v) => PrecisionPolicy::parse(v.as_str()?)?,
                Err(_) => PrecisionPolicy::Static, // pre-policy plan file
            },
            claimed_tpot_p99_ms: chosen.get("tpot_p99_ms")?.as_f64()?,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading plan {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// The base profile with the plan's transfer precision applied.
    pub fn scaled_profile(&self, base: &HardwareProfile) -> HardwareProfile {
        precision_scaled(base, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeClass;

    fn fleet() -> FleetSpec {
        FleetSpec::parse("rtx3080:4,jetson:4,nano:2").unwrap()
    }

    /// Closed-form evaluator: faster/bigger fleets decode faster, memory
    /// peaks follow the staged-resident formula. Deterministic in the
    /// candidate alone.
    fn fake_eval(c: &PlanCandidate, base: &HardwareProfile) -> PlanMeasurement {
        let scaled = c.scaled_profile(base);
        let n = c.fleet.n_nodes() as f64;
        let slow = c
            .fleet
            .entries()
            .iter()
            .map(|(cl, _)| cl.worker_profile(&scaled).effective_load_ms(c.chunks))
            .fold(0.0f64, f64::max);
        // Runtime downgrades shave load time off the critical path; the
        // importance-aware policy shaves slightly more (mirrors the real
        // engine's direction, not its magnitude).
        let policy_gain = match c.policy {
            PrecisionPolicy::Static => 0.0,
            PrecisionPolicy::Slack => 2.0,
            PrecisionPolicy::SlackImportance => 3.0,
        };
        let ms = 40.0 + slow / n - 2.0 * c.prefetch_depth as f64 - policy_gain;
        let peak = (c.prefetch_depth + 1) as f64 * scaled.expert_bytes + scaled.activation_bytes;
        PlanMeasurement {
            ms_per_token: ms,
            ttft_p99_ms: 500.0 / c.replicas as f64,
            tpot_p99_ms: ms * 1.2 / c.replicas as f64,
            slo_attainment: 0.9,
            main_peak_bytes: base.nonexpert_bytes,
            shadow_peak_bytes: base.shadow_model_bytes,
            worker_peak_bytes: vec![peak; c.fleet.n_nodes()],
        }
    }

    fn run(slo: f64) -> PlanReport {
        let base = HardwareProfile::rtx3090();
        let grid = PlanGrid::default();
        search(&fleet(), &base, 2, 4, slo, &grid, |c| Ok(fake_eval(c, &base))).unwrap()
    }

    #[test]
    fn search_prunes_window_infeasible_candidates() {
        let r = run(80.0);
        assert!(r.pruned > 0, "fp16 jetson/nano subsets must be pruned");
        assert!(!r.points.is_empty(), "nf4/int8 candidates survive");
        for p in &r.points {
            // Every surviving candidate's classes hold their window.
            let scaled = p.candidate.scaled_profile(&HardwareProfile::rtx3090());
            let n_groups = p.candidate.fleet.n_nodes() / 2;
            for (c, _) in p.candidate.fleet.entries() {
                assert!(
                    c.worker_profile(&scaled).reroute_feasible(1, n_groups, p.candidate.chunks),
                    "{} slipped through the window prefilter",
                    p.candidate.label()
                );
            }
        }
        // The full fp16 fleet is never measured (jetson misses its
        // window at every chunk count in the default grid).
        assert!(r.points.iter().all(|p| {
            !(p.candidate.precision == Precision::Fp16
                && p.candidate.fleet.entries().iter().any(|(c, _)| c.name == "jetson"))
        }));
    }

    #[test]
    fn pareto_frontier_has_no_dominated_member() {
        let r = run(80.0);
        let front: Vec<&PlanPoint> = r.points.iter().filter(|p| p.pareto).collect();
        assert!(!front.is_empty());
        for a in &front {
            for b in r.points.iter().filter(|p| p.mem_ok) {
                let dominates = b.meas.tpot_p99_ms <= a.meas.tpot_p99_ms
                    && b.total_gpu_bytes <= a.total_gpu_bytes
                    && b.cost <= a.cost
                    && (b.meas.tpot_p99_ms < a.meas.tpot_p99_ms
                        || b.total_gpu_bytes < a.total_gpu_bytes
                        || b.cost < a.cost);
                assert!(!dominates, "{} dominated by {}", a.candidate.label(), b.candidate.label());
            }
        }
    }

    #[test]
    fn chosen_plan_is_cheapest_slo_meeting_candidate() {
        let r = run(80.0);
        let chosen = r.chosen_point().expect("a plan qualifies at a loose SLO");
        assert!(chosen.meets_slo && chosen.mem_ok);
        for p in r.points.iter().filter(|p| p.mem_ok && p.meets_slo) {
            assert!(chosen.cost <= p.cost, "chosen must be cheapest");
        }
        // An impossible SLO chooses nothing.
        assert!(run(0.001).chosen.is_none());
    }

    #[test]
    fn plan_json_is_deterministic_and_round_trips_the_choice() {
        let base = HardwareProfile::rtx3090();
        let grid = PlanGrid::default();
        let go = || {
            let r = search(&fleet(), &base, 2, 4, 80.0, &grid, |c| Ok(fake_eval(c, &base)))
                .unwrap();
            plan_json(&r, &fleet(), &grid, 42).to_string()
        };
        let a = go();
        assert_eq!(a, go(), "same inputs must reproduce the file byte for byte");
        assert!(a.contains("\"bench\":\"plan\""));
        assert!(a.contains("\"chosen\":{"));
        assert!(a.contains("\"pareto\":true"));

        let doc = Json::parse(&a).unwrap();
        let choice = PlanChoice::from_json(&doc).unwrap();
        let r = search(&fleet(), &base, 2, 4, 80.0, &grid, |c| Ok(fake_eval(c, &base))).unwrap();
        let chosen = r.chosen_point().unwrap();
        assert_eq!(choice.fleet, chosen.candidate.fleet);
        assert_eq!(choice.precision, chosen.candidate.precision);
        assert_eq!(choice.chunks, chosen.candidate.chunks);
        assert_eq!(choice.replicas, chosen.candidate.replicas);
        assert!((choice.claimed_tpot_p99_ms - chosen.meas.tpot_p99_ms).abs() < 1e-9);
        // A plan that chose nothing refuses to load.
        let none = search(&fleet(), &base, 2, 4, 0.001, &grid, |c| Ok(fake_eval(c, &base)))
            .unwrap();
        let doc = plan_json(&none, &fleet(), &grid, 42);
        assert!(PlanChoice::from_json(&doc).is_err());
    }

    #[test]
    fn memory_budget_marks_over_peak_candidates() {
        // An evaluator whose measured peaks blow past jetson's 4 GB
        // budget: those candidates must be flagged mem_ok = false and
        // never chosen (a 10-jetson fleet at nf4 *is* window-feasible,
        // so it survives the prefilter and gets measured).
        let base = HardwareProfile::rtx3090();
        let f = FleetSpec::uniform(NodeClass::jetson(), 10).unwrap();
        let grid = PlanGrid {
            precisions: vec![Precision::Nf4],
            chunk_counts: vec![1],
            depths: vec![0],
            replicas: vec![1],
            cache_budgets: vec![0],
            policies: vec![PrecisionPolicy::Static],
        };
        let r = search(&f, &base, 2, 4, 1e6, &grid, |c| {
            let mut m = fake_eval(c, &base);
            for p in &mut m.worker_peak_bytes {
                *p = 5e9; // over jetson's 4 GB budget
            }
            Ok(m)
        })
        .unwrap();
        assert!(!r.points.is_empty(), "the nf4 jetson fleet must be measured");
        assert!(r.points.iter().all(|p| !p.mem_ok));
        assert!(r.chosen.is_none(), "over-budget plans are never chosen");
        assert!(
            r.points.iter().all(|p| !p.ledger_within_audit),
            "5 GB peaks also exceed the analytic audit bound"
        );
    }

    #[test]
    fn cache_budget_is_a_search_dimension_with_backward_compatible_labels() {
        let base = HardwareProfile::rtx3090();
        let f = FleetSpec::uniform(NodeClass::rtx3080(), 4).unwrap();
        let grid = PlanGrid {
            precisions: vec![Precision::Nf4],
            chunk_counts: vec![1],
            depths: vec![0],
            replicas: vec![1],
            cache_budgets: vec![0, 2],
            policies: vec![PrecisionPolicy::Static],
        };
        let r = search(&f, &base, 2, 1, 1e6, &grid, |c| Ok(fake_eval(c, &base))).unwrap();
        let labels: Vec<String> = r.points.iter().map(|p| p.candidate.label()).collect();
        // Budget 0 keeps the pre-cache label; budget 2 gets the /h suffix.
        assert!(labels.iter().any(|l| !l.contains("/h")), "{labels:?}");
        assert!(labels.iter().any(|l| l.ends_with("/h2")), "{labels:?}");
        // A budget too large for the class's memory floor is pruned, not
        // measured: nano (1 GB) cannot hold 8 extra nf4 experts.
        let tiny = FleetSpec::uniform(NodeClass::nano(), 2).unwrap();
        let big = PlanGrid { cache_budgets: vec![8], ..grid.clone() };
        let r = search(&tiny, &base, 2, 1, 1e6, &big, |c| Ok(fake_eval(c, &base))).unwrap();
        assert!(r.points.is_empty() && r.pruned > 0, "oversized cache budgets must be pruned");
        // Round trip: a chosen cached plan reads back its budget, and a
        // pre-cache plan file (no cache_hot key) defaults to 0.
        let full = PlanGrid { cache_budgets: vec![2], ..grid };
        let r = search(&f, &base, 2, 1, 1e6, &full, |c| Ok(fake_eval(c, &base))).unwrap();
        let doc = plan_json(&r, &f, &full, 7);
        assert_eq!(PlanChoice::from_json(&doc).unwrap().cache_hot, 2);
        let legacy = Json::parse(
            "{\"chosen\":{\"fleet\":\"rtx3080:4\",\"precision\":\"nf4\",\"chunks\":1,\
             \"prefetch_depth\":0,\"replicas\":1,\"tpot_p99_ms\":10.0}}",
        )
        .unwrap();
        assert_eq!(PlanChoice::from_json(&legacy).unwrap().cache_hot, 0);
    }

    #[test]
    fn replan_searches_only_the_neighborhood_of_the_deployed_plan() {
        let base = HardwareProfile::rtx3090();
        let f = FleetSpec::uniform(NodeClass::rtx3080(), 4).unwrap();
        let grid = PlanGrid {
            precisions: vec![Precision::Nf4],
            chunk_counts: vec![1, 2, 4, 8, 16],
            depths: vec![0, 1, 2, 3],
            replicas: vec![1, 2, 3, 4],
            cache_budgets: vec![0],
            policies: vec![PrecisionPolicy::Static],
        };
        let current = PlanChoice {
            fleet: f.clone(),
            precision: Precision::Nf4,
            chunks: 4,
            prefetch_depth: 0,
            replicas: 2,
            cache_hot: 0,
            policy: PrecisionPolicy::Static,
            claimed_tpot_p99_ms: 50.0,
        };
        let narrowed = grid.narrowed_around(&current);
        assert_eq!(narrowed.chunk_counts, vec![2, 4, 8]);
        assert_eq!(narrowed.depths, vec![0, 1], "edge values keep one neighbor");
        assert_eq!(narrowed.replicas, vec![1, 2, 3]);
        // replan reuses search on the narrowed grid: every measured
        // candidate stays within one grid step of the deployed plan,
        // and the candidate count is bounded regardless of grid size.
        let mut evals = 0usize;
        let r = replan(&f, &base, 2, 1, 1e6, &grid, &current, |c| {
            evals += 1;
            Ok(fake_eval(c, &base))
        })
        .unwrap();
        assert!(r.points.iter().all(|p| {
            narrowed.chunk_counts.contains(&p.candidate.chunks)
                && narrowed.depths.contains(&p.candidate.prefetch_depth)
                && narrowed.replicas.contains(&p.candidate.replicas)
        }));
        assert!(evals <= 3 * 2 * 3, "bounded candidate count, got {evals}");
        assert!(r.chosen.is_some(), "a loose SLO still chooses inside the neighborhood");
        // A deployed value that fell off the grid anchors its own
        // neighborhood, so the controller can step back onto the grid.
        let off = PlanChoice { chunks: 3, ..current };
        assert_eq!(grid.narrowed_around(&off).chunk_counts, vec![2, 3, 4]);
    }

    #[test]
    fn precision_policy_is_a_search_dimension_with_relaxed_window() {
        let base = HardwareProfile::rtx3090();
        // jetson at fp16 misses its Eq. (1) window even with 4 groups,
        // but the best-case NF4 stream fits: the static candidate is
        // pruned while the runtime-policy candidates get measured.
        let f = FleetSpec::uniform(NodeClass::jetson(), 4).unwrap();
        let grid = PlanGrid {
            precisions: vec![Precision::Fp16],
            chunk_counts: vec![1],
            depths: vec![0],
            replicas: vec![1],
            cache_budgets: vec![0],
            policies: vec![
                PrecisionPolicy::Static,
                PrecisionPolicy::Slack,
                PrecisionPolicy::SlackImportance,
            ],
        };
        let r = search(&f, &base, 1, 1, 1e6, &grid, |c| Ok(fake_eval(c, &base))).unwrap();
        assert!(r.pruned > 0, "static fp16 on jetson must be pruned");
        assert_eq!(r.points.len(), 2, "both runtime policies survive the relaxed filter");
        assert!(r.points.iter().all(|p| p.candidate.policy != PrecisionPolicy::Static));
        // Labels carry the policy suffix only for non-static candidates.
        let labels: Vec<String> = r.points.iter().map(|p| p.candidate.label()).collect();
        assert!(labels.iter().any(|l| l.ends_with("/slack")), "{labels:?}");
        assert!(labels.iter().any(|l| l.ends_with("/slack-importance")), "{labels:?}");
        // The chosen plan round-trips its policy through the JSON.
        let doc = plan_json(&r, &f, &grid, 7);
        let choice = PlanChoice::from_json(&doc).unwrap();
        assert_eq!(choice.policy, PrecisionPolicy::SlackImportance, "fastest fake policy wins");
        // A pre-policy plan file (no policy key) reads back as static.
        let legacy = Json::parse(
            "{\"chosen\":{\"fleet\":\"rtx3080:4\",\"precision\":\"nf4\",\"chunks\":1,\
             \"prefetch_depth\":0,\"replicas\":1,\"cache_hot\":0,\"tpot_p99_ms\":10.0}}",
        )
        .unwrap();
        assert_eq!(PlanChoice::from_json(&legacy).unwrap().policy, PrecisionPolicy::Static);
    }
}
