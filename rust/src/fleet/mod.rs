//! Heterogeneous fleet composition and the SLO-driven deployment
//! planner (DESIGN.md §10).
//!
//! The paper promises MoE inference on *low-cost, mixed* edge hardware,
//! which makes deployment a configuration-search problem: which fleet
//! composition keeps the Eq. (1) no-stall window feasible, at what
//! transfer precision and chunking, and at what memory/cost? This module
//! supplies the two halves:
//!
//! * [`FleetSpec`] — a named composition of [`NodeClass`]es
//!   (`rtx3080:4,jetson:4,nano:2`), parsed from the CLI, validated
//!   against the §3.1 profile invariants, and threaded into
//!   [`crate::cluster::Cluster`] / [`crate::coordinator::OdMoeConfig`] so
//!   every worker books its own class's durations.
//! * [`planner`] — a grid search over (class subset, transfer precision,
//!   chunk count, prefetch depth, replica count) that scores candidates
//!   with the real engine in virtual time, prunes by the per-class
//!   Eq. (1) window and per-node memory budgets, and emits a
//!   deterministic Pareto frontier (`BENCH_plan.json`) plus a chosen
//!   plan `od-moe serve --plan` can run directly.
//!
//! SlimCaching (arXiv 2507.06567) frames the expert-placement-across-
//! heterogeneous-devices optimization this reifies; HOBBIT
//! (arXiv 2411.01433) is where precision-as-a-deployment-knob comes
//! from.

pub mod planner;

use anyhow::{bail, ensure, Result};

use crate::cluster::{Cluster, HardwareProfile, NodeClass};
use crate::coordinator::SlotMap;

pub use planner::{replan, PlanCandidate, PlanChoice, PlanGrid, PlanMeasurement, PlanReport};

/// A named fleet composition: node classes with counts, in declaration
/// order. Worker ids are assigned by expanding the entries in order
/// (`rtx3080:4,jetson:2` → workers 0..4 are rtx3080, 4..6 jetson).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    entries: Vec<(NodeClass, usize)>,
}

impl FleetSpec {
    /// Parse a `class:count[,class:count..]` spec (`count` defaults to 1
    /// when omitted). Class names resolve through [`NodeClass::preset`];
    /// duplicate classes are rejected so the canonical [`FleetSpec::label`]
    /// round-trips through this parser.
    pub fn parse(s: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let part = part.trim();
            let (name, count) = match part.split_once(':') {
                Some((n, c)) => {
                    let count: usize = c
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad node count in {part:?}"))?;
                    (n.trim(), count)
                }
                None => (part, 1),
            };
            let Some(class) = NodeClass::preset(name) else {
                bail!(
                    "unknown node class {name:?} (have {})",
                    NodeClass::PRESET_NAMES.join("|")
                );
            };
            ensure!(count >= 1, "node class {name:?} needs a count >= 1");
            ensure!(
                !entries.iter().any(|(c, _): &(NodeClass, usize)| c.name == name),
                "node class {name:?} listed twice — merge the counts"
            );
            entries.push((class, count));
        }
        Self::from_entries(entries)
    }

    /// Build from explicit entries (tests and the planner's subsets).
    /// Each class is validated both at the class level and as a
    /// materialized worker profile over the paper's base testbed
    /// ([`HardwareProfile::validate`] — the §3.1 invariants), so a bad
    /// preset fails at parse time, not mid-simulation; engines
    /// re-validate against their actual base profile.
    pub fn from_entries(entries: Vec<(NodeClass, usize)>) -> Result<Self> {
        ensure!(!entries.is_empty(), "a fleet needs at least one node class");
        let base = HardwareProfile::rtx3090();
        for (c, count) in &entries {
            c.validate()?;
            c.worker_profile(&base).validate()?;
            ensure!(*count >= 1, "node class {:?} needs a count >= 1", c.name);
        }
        Ok(Self { entries })
    }

    /// A single-class fleet of `count` nodes.
    pub fn uniform(class: NodeClass, count: usize) -> Result<Self> {
        Self::from_entries(vec![(class, count)])
    }

    /// Validate every class and its materialized worker profile against
    /// `base` ([`HardwareProfile::validate`] — the §3.1 invariants).
    pub fn validate(&self, base: &HardwareProfile) -> Result<()> {
        for (c, _) in &self.entries {
            c.validate()?;
            c.worker_profile(base).validate()?;
        }
        Ok(())
    }

    pub fn entries(&self) -> &[(NodeClass, usize)] {
        &self.entries
    }

    /// Total worker nodes in the fleet.
    pub fn n_nodes(&self) -> usize {
        self.entries.iter().map(|(_, n)| n).sum()
    }

    /// Canonical spec string (`class:count,..` in declaration order);
    /// [`FleetSpec::parse`] of this is the identity, which is what lets
    /// `BENCH_plan.json` carry a chosen sub-fleet as plain text.
    pub fn label(&self) -> String {
        self.entries
            .iter()
            .map(|(c, n)| format!("{}:{n}", c.name))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// One [`NodeClass`] per worker, in worker-id order.
    pub fn node_classes(&self) -> Vec<NodeClass> {
        let mut out = Vec::with_capacity(self.n_nodes());
        for (c, n) in &self.entries {
            out.extend(vec![c.clone(); *n]);
        }
        out
    }

    /// The sub-fleet keeping only the entries whose index is set in
    /// `mask` (bit `i` = entry `i`); `None` when the mask selects
    /// nothing. The planner enumerates these.
    pub fn subset(&self, mask: usize) -> Option<FleetSpec> {
        let entries: Vec<(NodeClass, usize)> = self
            .entries
            .iter()
            .enumerate()
            .filter(|(i, _)| mask & (1 << i) != 0)
            .map(|(_, e)| e.clone())
            .collect();
        if entries.is_empty() {
            None
        } else {
            Some(FleetSpec { entries })
        }
    }

    /// The per-replica node-class bill: Σ count × unit cost.
    pub fn bill(&self) -> f64 {
        self.entries.iter().map(|(c, n)| c.unit_cost * *n as f64).sum()
    }
}

/// Capability-aware slot construction over a heterogeneous cluster:
/// first-fit, preferring workers whose class keeps the one-slot Eq. (1)
/// window feasible under the engine's chunking
/// ([`HardwareProfile::reroute_feasible`] on the node's own class
/// profile), so under-provisioned classes start as spares whenever the
/// fleet has more nodes than slots. On a uniform cluster every worker is
/// equally (in)capable and this reduces to the identity assignment —
/// bit-identical to [`SlotMap::new`].
pub fn capability_slots(cluster: &Cluster, group_size: usize, chunks: usize) -> SlotMap {
    let n = cluster.n_workers();
    let n_groups = n / group_size;
    SlotMap::first_fit(n, group_size, n_groups, |w| {
        cluster.worker_profile(w).reroute_feasible(1, n_groups, chunks)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_through_the_canonical_label() {
        let f = FleetSpec::parse("rtx3080:4,jetson:4,nano:2").unwrap();
        assert_eq!(f.n_nodes(), 10);
        assert_eq!(f.label(), "rtx3080:4,jetson:4,nano:2");
        assert_eq!(FleetSpec::parse(&f.label()).unwrap(), f);
        // Count defaults to 1; whitespace tolerated.
        let g = FleetSpec::parse(" rtx3090 , nano:3 ").unwrap();
        assert_eq!(g.label(), "rtx3090:1,nano:3");
        assert_eq!(g.n_nodes(), 4);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FleetSpec::parse("").is_err(), "empty fleet");
        assert!(FleetSpec::parse("gtx1080:4").is_err(), "unknown class");
        assert!(FleetSpec::parse("rtx3090:0").is_err(), "zero count");
        assert!(FleetSpec::parse("rtx3090:x").is_err(), "bad count");
        assert!(FleetSpec::parse("nano:1,nano:2").is_err(), "duplicate class");
    }

    #[test]
    fn node_classes_expand_in_worker_id_order() {
        let f = FleetSpec::parse("rtx3080:2,nano:1").unwrap();
        let names: Vec<&str> = f.node_classes().iter().map(|c| c.name).collect();
        assert_eq!(names, ["rtx3080", "rtx3080", "nano"]);
        f.validate(&HardwareProfile::rtx3090()).unwrap();
    }

    #[test]
    fn subsets_and_bill() {
        let f = FleetSpec::parse("rtx3080:4,jetson:4,nano:2").unwrap();
        assert_eq!(f.subset(0), None);
        assert_eq!(f.subset(0b001).unwrap().label(), "rtx3080:4");
        assert_eq!(f.subset(0b110).unwrap().label(), "jetson:4,nano:2");
        assert_eq!(f.subset(0b111).unwrap(), f);
        let bill = f.bill();
        assert!((bill - (4.0 * 0.6 + 4.0 * 0.35 + 2.0 * 0.15)).abs() < 1e-12, "{bill}");
        assert!(f.subset(0b001).unwrap().bill() < bill);
    }

    #[test]
    fn capability_slots_spare_the_incapable_classes() {
        let base = HardwareProfile::rtx3090();
        // Jetsons listed FIRST, so id order alone would hand them the
        // first slots; they miss the Eq. (1) window at full precision
        // while the 3090s hold it at 5 groups, so the capable 3090s take
        // the first 8 slots and the jetsons host only the shortfall.
        let f = FleetSpec::parse("jetson:2,rtx3090:8").unwrap();
        let cluster = Cluster::with_classes(base.clone(), f.node_classes());
        let m = capability_slots(&cluster, 2, 1);
        assert_eq!(m.n_groups(), 5);
        assert_eq!(m.workers_of(0), vec![2, 3], "3090s first despite higher ids");
        assert_eq!(m.workers_of(4), vec![0, 1], "jetsons host only the shortfall");

        // With one jetson and an uneven split, the spare slot is exactly
        // the incapable node: it starts idle instead of hosting.
        let f = FleetSpec::parse("jetson:1,rtx3090:8").unwrap();
        let cluster = Cluster::with_classes(base, f.node_classes());
        let m = capability_slots(&cluster, 2, 1);
        assert_eq!(m.n_groups(), 4);
        assert_eq!(m.load_of(0), 0, "incapable jetson starts as the spare");
        for g in 0..4 {
            for w in m.workers_of(g) {
                assert!(w >= 1, "every slot on a window-capable 3090");
            }
        }
    }

    #[test]
    fn uniform_capability_slots_are_the_identity_map() {
        let cluster = Cluster::new(HardwareProfile::rtx3090(), 8);
        assert_eq!(capability_slots(&cluster, 2, 1), SlotMap::new(8, 2));
        assert_eq!(capability_slots(&cluster, 2, 8), SlotMap::new(8, 2));
    }
}
