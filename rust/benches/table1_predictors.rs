//! Table 1: expert-activation prediction baselines vs SEP.
//! Paper reference: AdapMoE 0.86, DAOP 0.84, HOBBIT 0.91 (4 layers ahead),
//! Mixtral-Offloading ~0.80 / fMoE <0.85 (cache-hit), SEP 0.9567–0.9994.

mod common;

use odmoe::model::Precision;
use odmoe::predictor::{
    AlignmentConfig, GateLookahead, MultiLayerGate, RandomPredictor, Statistical,
};
use odmoe::util::table::Table;
use odmoe::workload::{recall, Corpus};

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let cfg = s.rt.cfg.clone();
    let (prompts, out_tokens) = s.recall_size();
    let corpus = Corpus::generate(s.seed ^ 11, prompts, 16, cfg.vocab_size as u32);

    println!("# Table 1 — expert-activation prediction (Q={prompts}, N={out_tokens})\n");
    let mut table = Table::new(&["predictor", "recall", "lookahead", "paper"]);

    let mut gl = GateLookahead::new(&ws);
    let (r, n) = recall::baseline_recall(&s.rt, &ws, &mut gl, &corpus, out_tokens)?;
    table.row(&["gate-lookahead (AdapMoE/DAOP/MxOff)".into(), format!("{r:.4}"),
                "1 layer".into(), "0.86 / 0.84 / ~0.80".into()]);
    let _ = n;

    let mut ml = MultiLayerGate::new(&ws, 4);
    let (r, _) = recall::baseline_recall(&s.rt, &ws, &mut ml, &corpus, out_tokens)?;
    table.row(&["multi-layer gate (HOBBIT)".into(), format!("{r:.4}"),
                "4 layers".into(), "0.91".into()]);

    let mut st = Statistical::new(cfg.n_layers, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(&s.rt, &ws, &mut st, &corpus, out_tokens)?;
    table.row(&["statistical (EdgeMoE/fMoE)".into(), format!("{r:.4}"),
                "any".into(), "<0.85 (hit rate)".into()]);

    let mut rp = RandomPredictor::new(s.seed, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(&s.rt, &ws, &mut rp, &corpus, out_tokens)?;
    table.row(&["random (control)".into(), format!("{r:.4}"),
                "any".into(), "k/E = 0.25".into()]);

    for (p, paper) in [
        (Precision::Nf4, "0.9567"),
        (Precision::Int8, "0.9734"),
        (Precision::Fp16, "0.9994"),
    ] {
        let stats = recall::sep_recall(
            &s.rt, &ws, p, AlignmentConfig::every_iteration(), &corpus, out_tokens,
        )?;
        table.row(&[
            format!("SEP {} (ours)", p.label()),
            format!("{:.4}", stats.recall()),
            "whole model".into(),
            paper.into(),
        ]);
    }
    table.print();
    println!("\npaper: SEP beats every baseline at every precision; the ordering");
    println!("SEP > multi-layer/gate heuristics > statistical > random must hold.");
    Ok(())
}
