//! Fig. 9: decoding speed vs token/KV alignment periods (1/2/4/8/16) on
//! the RTX 3090 testbed. Paper reference: best speed at T=1, KV=1 —
//! reduced prediction error outweighs the late-departure cost there.

mod common;

use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::predictor::{AlignPeriod, AlignmentConfig};
use odmoe::util::table::Table;
use odmoe::workload::speed::PAPER_LAYER_SCALE;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let (prompts, outs) = s.speed_size();
    let out_tokens = *outs.last().unwrap();
    let corpus = Corpus::generate(s.seed ^ 9, prompts, 16, s.rt.cfg.vocab_size as u32);
    let periods = [1usize, 2, 4, 8, 16];

    println!("# Fig. 9 — decode tok/s* vs alignment periods (rtx3090)\n");
    let headers: Vec<String> = std::iter::once("token\\KV".into())
        .chain(periods.iter().map(|p| format!("KV={p}")))
        .collect();
    let refs: Vec<&str> = headers.iter().map(|x| x.as_str()).collect();
    let mut table = Table::new(&refs);
    let mut best = (0.0f64, 0usize, 0usize);
    for &tp in &periods {
        let mut row = vec![format!("T={tp}")];
        for &kp in &periods {
            let cfg = OdMoeConfig {
                align: AlignmentConfig {
                    token_period: AlignPeriod::Every(tp),
                    kv_period: AlignPeriod::Every(kp),
                },
                ..OdMoeConfig::default()
            };
            let mut engine = OdMoeEngine::new(&s.rt, ws.clone(), cfg)?;
            let mut total_tps = 0.0;
            for prompt in &corpus.prompts {
                engine.reset()?;
                let r = engine.run_prompt(prompt, out_tokens, false)?;
                total_tps += r.decode_tps() / PAPER_LAYER_SCALE;
            }
            let tps = total_tps / corpus.prompts.len() as f64;
            if tps > best.0 {
                best = (tps, tp, kp);
            }
            row.push(format!("{tps:.3}"));
        }
        table.row(&row);
    }
    table.print();
    println!("\nbest: {:.3} tok/s at T={}, KV={}   (paper: optimum at T=1, KV=1)",
             best.0, best.1, best.2);
    Ok(())
}
