//! Fig. 6: average prediction recall for token/KV alignment periods in
//! {1, 2, 4, 8, 16} (INT8 shadow). Paper reference: T1_KV1 tops out above
//! 0.9734; recall degrades monotonically as either period grows, with the
//! token period mattering more.

mod common;

use odmoe::model::Precision;
use odmoe::predictor::{AlignPeriod, AlignmentConfig};
use odmoe::util::table::Table;
use odmoe::workload::{recall, Corpus};

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let (prompts, out_tokens) = s.recall_size();
    let corpus = Corpus::generate(s.seed ^ 6, prompts, 16, s.rt.cfg.vocab_size as u32);
    let periods = [1usize, 2, 4, 8, 16];

    println!("# Fig. 6 — recall vs alignment periods (INT8 shadow, Q={prompts}, N={out_tokens})\n");
    let headers: Vec<String> = std::iter::once("token\\KV".to_string())
        .chain(periods.iter().map(|p| format!("KV={p}")))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for &tp in &periods {
        let mut row = vec![format!("T={tp}")];
        for &kp in &periods {
            let align = AlignmentConfig {
                token_period: AlignPeriod::Every(tp),
                kv_period: AlignPeriod::Every(kp),
            };
            let stats =
                recall::sep_recall(&s.rt, &ws, Precision::Int8, align, &corpus, out_tokens)?;
            row.push(format!("{:.4}", stats.recall()));
        }
        table.row(&row);
    }
    table.print();
    println!("\npaper: T1_KV1 >= 0.9734; larger periods reduce recall, token");
    println!("period dominating (T16_KV1 loses more than T1_KV16).");
    Ok(())
}
