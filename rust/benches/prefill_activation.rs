//! §3.3 footnote 3: experts activated during prefill. Paper reference:
//! 16-token prompts activate 7.6/8 experts per layer on average; 128-token
//! prompts activate all 8 with 99.8% probability — the justification for
//! loading every expert (and skipping prediction) during prefill.

mod common;

use odmoe::engine::ModelState;
use odmoe::util::table::Table;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let cfg = s.rt.cfg.clone();
    let prompts = if common::big() { 16 } else { 4 };

    println!("# §3.3 — expert activations during batched prefill\n");
    let mut state = ModelState::new(&s.rt, ws)?;
    let mut table = Table::new(&[
        "prompt len", "avg experts/layer", "P(all 8 active)", "paper",
    ]);
    for &len in &[16usize, 128] {
        let corpus = Corpus::generate(s.seed ^ 13, prompts, len, cfg.vocab_size as u32);
        let mut sum = 0.0;
        let mut full = 0usize;
        let mut layers = 0usize;
        for prompt in &corpus.prompts {
            state.reset();
            for layer in state.prefill_activations(prompt)? {
                let n = layer.iter().filter(|&&b| b).count();
                sum += n as f64;
                full += (n == cfg.n_experts) as usize;
                layers += 1;
            }
        }
        let paper = if len == 16 { "7.6 / 8" } else { "all 8 at 99.8%" };
        table.row(&[
            len.to_string(),
            format!("{:.2}", sum / layers as f64),
            format!("{:.1}%", 100.0 * full as f64 / layers as f64),
            paper.to_string(),
        ]);
    }
    table.print();
    Ok(())
}
