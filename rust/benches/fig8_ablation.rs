//! Fig. 8: decoding-speed ablation, Cases 1–6.
//!
//!  1. shadow + token & KV alignment every iteration
//!  2. shadow + token alignment only
//!  3. shadow + KV alignment only
//!  4. shadow, no alignment
//!  5. no shadow, random prefetch
//!  6. no shadow, load on gate result only
//!
//! Paper reference: monotonic decrease from Case 1 to Case 6; the
//! Case-1→3 gap (no token align) exceeds the Case-1→2 gap (no KV align).

mod common;

use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine, PredictorMode};
use odmoe::metrics::{mean, std_dev};
use odmoe::predictor::AlignmentConfig;
use odmoe::util::table::Table;
use odmoe::workload::speed::PAPER_LAYER_SCALE;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let (prompts, outs) = s.speed_size();
    let out_tokens = *outs.last().unwrap();
    let corpus = Corpus::generate(s.seed ^ 8, prompts.max(2), 16, s.rt.cfg.vocab_size as u32);

    let cases: Vec<(&str, PredictorMode, AlignmentConfig)> = vec![
        ("1: token+KV aligned", PredictorMode::Sep, AlignmentConfig::every_iteration()),
        ("2: token only", PredictorMode::Sep, AlignmentConfig::token_only()),
        ("3: KV only", PredictorMode::Sep, AlignmentConfig::kv_only()),
        ("4: no alignment", PredictorMode::Sep, AlignmentConfig::none()),
        ("5: random prefetch", PredictorMode::Random, AlignmentConfig::none()),
        ("6: no prefetch", PredictorMode::None, AlignmentConfig::none()),
    ];

    println!("# Fig. 8 — decoding-speed ablation ((16, {out_tokens}) config)\n");
    let mut table = Table::new(&["case", "decode tok/s*", "std", "stall ms/tok", "recall"]);
    for (label, predictor, align) in cases {
        let cfg = OdMoeConfig { predictor, align, ..OdMoeConfig::default() };
        let mut engine = OdMoeEngine::new(&s.rt, ws.clone(), cfg)?;
        let mut tps = Vec::new();
        let mut stalls = Vec::new();
        let mut correct = 0usize;
        let mut total = 0usize;
        for prompt in &corpus.prompts {
            engine.reset()?;
            let r = engine.run_prompt(prompt, out_tokens, false)?;
            tps.push(r.decode_tps() / PAPER_LAYER_SCALE);
            stalls.push(r.stall_ms / (r.tokens.len() - 1) as f64);
            for per_layer in &r.correct_per_token {
                correct += per_layer.iter().sum::<usize>();
                total += per_layer.len() * s.rt.cfg.top_k;
            }
        }
        let recall = if total > 0 {
            format!("{:.4}", correct as f64 / total as f64)
        } else {
            "-".into()
        };
        table.row(&[
            label.into(),
            format!("{:.3}", mean(&tps)),
            format!("{:.3}", std_dev(&tps)),
            format!("{:.2}", mean(&stalls)),
            recall,
        ]);
    }
    table.print();
    println!("\n(* paper-scale: 32-layer equivalent)");
    println!("paper: monotonic decrease case 1 -> 6; removing token alignment");
    println!("(case 3) costs more than removing KV alignment (case 2).");
    Ok(())
}
