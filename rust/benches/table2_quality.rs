//! Table 2(iii): answer quality → output fidelity vs the FP32 reference
//! (substitution documented in DESIGN.md §2). Paper reference: OD-MoE
//! matches the full-precision engines exactly; every quantizing/skipping
//! baseline degrades, AdapMoE worst.

mod common;

use odmoe::coordinator::baselines::{FullyCachedEngine, OffloadConfig, OffloadEngine};
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::util::table::Table;
use odmoe::workload::{fidelity, Corpus};

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let l = s.rt.cfg.n_layers;
    let (prompts, out_tokens) = s.recall_size();
    let corpus = Corpus::generate(s.seed ^ 12, prompts, 16, s.rt.cfg.vocab_size as u32);

    println!("# Table 2(iii) — output fidelity vs FP32 reference (Q={prompts}, N={out_tokens})\n");
    let reference = fidelity::reference(&s.rt, &ws, &corpus, out_tokens)?;

    let mut table = Table::new(&[
        "engine", "token match", "mean KL", "diverged", "paper analogue",
    ]);
    let mut eval = |name: &str, engine: &mut dyn Engine, paper: &str| -> anyhow::Result<()> {
        let fid = fidelity::evaluate(engine, &reference, &corpus, out_tokens)?;
        let div = fid.first_divergence.iter().filter(|d| d.is_some()).count();
        table.row(&[
            name.into(),
            format!("{:.4}", fid.token_match_rate()),
            format!("{:.6}", fid.mean_kl()),
            format!("{div}/{prompts}"),
            paper.into(),
        ]);
        Ok(())
    };

    let mut tf = FullyCachedEngine::new(&s.rt, ws.clone())?;
    eval("transformers (fp32)", &mut tf, "reference quality")?;
    let mut od = OdMoeEngine::new(&s.rt, ws.clone(), OdMoeConfig::default())?;
    eval("od-moe (ours)", &mut od, "matches reference on all 10 benchmarks")?;
    let mut e = OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::moe_infinity(l))?;
    eval("moe-infinity (fp16 experts)", &mut e, "2nd best baseline")?;
    let mut e = OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::mixtral_offloading(l))?;
    eval("mixtral-offloading (4-bit)", &mut e, "mid")?;
    let mut e = OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::hobbit(l))?;
    eval("hobbit (mixed int8)", &mut e, "lower")?;
    let mut e = OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::adapmoe(l))?;
    eval("adapmoe (4-bit + skip)", &mut e, "worst (0% BigCode, 4.47 MT-bench)")?;

    table.print();
    println!("\npaper shape: OD-MoE == full precision exactly; fidelity ordering");
    println!("moe-infinity > mixtral-offloading > hobbit > adapmoe.");
    Ok(())
}
