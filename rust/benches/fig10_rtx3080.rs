//! Fig. 10: decoding speed with worker GPUs replaced by RTX 3080s; token
//! period fixed at 1, KV period swept over {1, 2, 4, 8, 16, 32}.
//! Paper reference: the optimum *shifts* to KV period 4 — slower workers
//! change the late-departure/accuracy balance.

mod common;

use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::predictor::{AlignPeriod, AlignmentConfig};
use odmoe::util::table::Table;
use odmoe::workload::speed::PAPER_LAYER_SCALE;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let (prompts, outs) = s.speed_size();
    let out_tokens = *outs.last().unwrap();
    let corpus = Corpus::generate(s.seed ^ 10, prompts, 16, s.rt.cfg.vocab_size as u32);

    println!("# Fig. 10 — decode tok/s* with RTX 3080 workers (T=1, KV swept)\n");
    let mut table = Table::new(&["KV period", "rtx3080 workers", "rtx3090 (Fig. 9 ref)"]);
    let mut best = (0.0f64, 0usize);
    for &kp in &[1usize, 2, 4, 8, 16, 32] {
        let mut row = vec![kp.to_string()];
        for profile in [HardwareProfile::rtx3080_workers(), HardwareProfile::rtx3090()] {
            let cfg = OdMoeConfig {
                align: AlignmentConfig {
                    token_period: AlignPeriod::Every(1),
                    kv_period: AlignPeriod::Every(kp),
                },
                profile: profile.clone(),
                ..OdMoeConfig::default()
            };
            let mut engine = OdMoeEngine::new(&s.rt, ws.clone(), cfg)?;
            let mut total = 0.0;
            for prompt in &corpus.prompts {
                engine.reset()?;
                let r = engine.run_prompt(prompt, out_tokens, false)?;
                total += r.decode_tps() / PAPER_LAYER_SCALE;
            }
            let tps = total / corpus.prompts.len() as f64;
            if profile.name == "rtx3080-workers" && tps > best.0 {
                best = (tps, kp);
            }
            row.push(format!("{tps:.3}"));
        }
        table.row(&row);
    }
    table.print();
    println!("\nbest 3080 speed: {:.3} tok/s at KV={}   (paper: optimum at KV=4)",
             best.0, best.1);
    Ok(())
}
