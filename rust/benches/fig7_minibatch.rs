//! Fig. 7: prefill with one large batch vs pipelined mini-batches.
//! Paper reference: mini-batching lowers prefill latency by overlapping
//! LAN transfer with expert compute, despite larger total compute time.

use odmoe::cluster::{Cluster, HardwareProfile};
use odmoe::coordinator::prefill::simulate_odmoe_prefill;
use odmoe::model::ModelConfig;
use odmoe::util::table::Table;

fn main() {
    let cfg = ModelConfig::default();
    println!("# Fig. 7 — prefill TTFT: single batch vs mini-batches\n");
    let mut table = Table::new(&[
        "prompt len", "mini-batches", "TTFT ms", "vs single", "worker wait ms",
    ]);
    for &len in &[16usize, 128] {
        let single = {
            let mut c = Cluster::new(HardwareProfile::rtx3090(), 8);
            simulate_odmoe_prefill(&mut c, &cfg, len, 1).ttft_ms
        };
        for &b in &[1usize, 2, 4, 8, 16, 32] {
            let mut c = Cluster::new(HardwareProfile::rtx3090(), 8);
            let t = simulate_odmoe_prefill(&mut c, &cfg, len, b);
            table.row(&[
                len.to_string(),
                b.to_string(),
                format!("{:.1}", t.ttft_ms),
                format!("{:+.1}%", (t.ttft_ms / single - 1.0) * 100.0),
                format!("{:.1}", t.worker_wait_ms),
            ]);
        }
    }
    table.print();
    println!("\npaper: Fig. 7(b)'s pipelined mini-batches beat Fig. 7(a)'s single");
    println!("batch; the optimum is an interior mini-batch count (per-message");
    println!("latency and lost batching efficiency eventually dominate).");
}
