//! Design-choice ablations beyond the paper's figures (DESIGN.md §5):
//!
//! 1. Worker-count scaling — how decode speed and the Eq. (1) feasibility
//!    boundary move with N_W (the paper fixes N_W = 8; this sweep shows
//!    why: 4 groups is the first bottleneck-free configuration and more
//!    buys little).
//! 2. PCIe-bandwidth sensitivity — where the cacheless design's knife
//!    edge sits (crossover from I/O-bound to compute-bound).
//! 3. Shadow-speed sensitivity — how much slack SEP's lookahead needs.

mod common;

use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::{Engine, GroupSchedule, OdMoeConfig, OdMoeEngine};
use odmoe::util::table::Table;
use odmoe::workload::speed::PAPER_LAYER_SCALE;
use odmoe::workload::Corpus;

fn run_once(
    s: &common::Setup,
    ws: &odmoe::model::WeightStore,
    cfg: OdMoeConfig,
    prompt: &[u32],
    out: usize,
) -> anyhow::Result<(f64, f64)> {
    let mut e = OdMoeEngine::new(&s.rt, ws.clone(), cfg)?;
    let r = e.run_prompt(prompt, out, false)?;
    Ok((r.decode_tps() / PAPER_LAYER_SCALE, r.stall_ms / (out - 1) as f64))
}

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let prompt = &Corpus::generate(s.seed ^ 21, 1, 16, s.rt.cfg.vocab_size as u32).prompts[0];
    let out = 16;

    // ---- 1. worker-count scaling ----------------------------------------
    println!("# Ablation A — worker-count scaling (top-2 groups)\n");
    let mut t = Table::new(&[
        "workers", "groups", "Eq.1 window ms", "bottleneck-free", "decode tok/s*", "stall ms/tok",
    ]);
    for n_workers in [2usize, 4, 6, 8, 12, 16] {
        let p = HardwareProfile::rtx3090();
        let sched = GroupSchedule::new(n_workers, s.rt.cfg.top_k);
        let window = sched.t_maxload(p.t_main_ms(), p.t_worker_ms());
        let cfg = OdMoeConfig { n_workers, ..OdMoeConfig::default() };
        let (tps, stall) = run_once(&s, &ws, cfg, prompt, out)?;
        t.row(&[
            n_workers.to_string(),
            sched.n_groups().to_string(),
            format!("{window:.1}"),
            if sched.io_bottleneck_free(&p) { "yes" } else { "NO" }.into(),
            format!("{tps:.3}"),
            format!("{stall:.1}"),
        ]);
    }
    t.print();
    println!("\nexpected: speed grows steeply until the first bottleneck-free");
    println!("config (8 workers / 4 groups — the paper's testbed), then flattens.\n");

    // ---- 2. PCIe-bandwidth sensitivity ----------------------------------
    println!("# Ablation B — PCIe bandwidth sensitivity (8 workers)\n");
    let mut t = Table::new(&["pcie GB/s", "load ms", "decode tok/s*", "stall ms/tok"]);
    for gbps in [5.0, 10.0, 15.0, 20.0, 25.0, 35.0, 50.0] {
        let mut profile = HardwareProfile::rtx3090();
        profile.pcie_gbps = gbps;
        let load = profile.expert_load_ms(1.0);
        let cfg = OdMoeConfig { profile, ..OdMoeConfig::default() };
        let (tps, stall) = run_once(&s, &ws, cfg, prompt, out)?;
        t.row(&[
            format!("{gbps:.0}"),
            format!("{load:.1}"),
            format!("{tps:.3}"),
            format!("{stall:.1}"),
        ]);
    }
    t.print();
    println!("\nexpected: I/O-bound below the Eq. (1) crossover (~24 GB/s for");
    println!("500 MB loads), then compute-bound and flat — the cacheless design");
    println!("only works at edge-realistic PCIe if loads are FP16-compressed.\n");

    // ---- 3. shadow-speed sensitivity ------------------------------------
    println!("# Ablation C — shadow-node speed sensitivity\n");
    let mut t = Table::new(&["shadow layer ms", "vs t_M+t_W", "decode tok/s*", "stall ms/tok"]);
    let p0 = HardwareProfile::rtx3090();
    let budget = p0.t_main_ms() + p0.t_worker_ms();
    for factor in [0.25, 0.5, 0.75, 1.0, 1.25, 1.5] {
        let mut profile = HardwareProfile::rtx3090();
        profile.t_shadow_layer_ms = budget * factor;
        let cfg = OdMoeConfig { profile: profile.clone(), ..OdMoeConfig::default() };
        let (tps, stall) = run_once(&s, &ws, cfg, prompt, out)?;
        t.row(&[
            format!("{:.2}", profile.t_shadow_layer_ms),
            format!("{:.2}x", factor),
            format!("{tps:.3}"),
            format!("{stall:.1}"),
        ]);
    }
    t.print();
    println!("\nexpected: once the shadow is slower than the pipeline (>1.0x),");
    println!("predictions arrive late, loads fall back to the reactive path and");
    println!("speed collapses toward the no-prefetch ablation case.");
    Ok(())
}
