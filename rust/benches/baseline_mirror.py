#!/usr/bin/env python3
"""Independent mirror of `od-moe bench`'s precision/* and control/*
virtual metrics.

Recomputes the `precision/<class>/loads_<tier>` tier tallies and the
`control/grid_*` / `control/episode_*` SLO-controller tallies of the
committed baseline (rust/benches/perf_baseline.json) from the same
closed-form models as `cluster::HardwareProfile`,
`coordinator::precision::PrecisionController`, and
`control::{classify, ControlState}`, without touching the Rust crate.
The counts are small integers and every comparison in both grids clears
its boundary strictly, so agreement is exact, not band-dependent.

Usage:
    python3 rust/benches/baseline_mirror.py          # print the JSON
    python3 rust/benches/baseline_mirror.py --check  # diff vs the file

`od-moe bench --write-baseline` pins whatever the crate currently
computes; this script is the cross-check that the pinned numbers follow
from the documented models (DESIGN.md §14, §15).
"""

import json
import sys

# cluster::HardwareProfile::rtx3090() — the base (main/LAN/model) profile.
BASE = {
    "t_nonexpert_ms": 3.5,
    "lan_gbps": 1.0,
    "lan_lat_ms": 0.15,
    "embed_msg_bytes": 16_384.0,
    "expert_bytes": 500e6,
}

# cluster::NodeClass presets — the worker-side knobs worker_profile()
# overlays on BASE (name, t_expert_gpu_ms, pcie_gbps, pcie_lat_ms,
# chunk_overhead_ms).
CLASSES = [
    ("rtx3090", 1.4, 25.0, 0.2, 0.01),
    ("rtx3080", 1.9, 22.0, 0.2, 0.01),
    ("jetson", 3.2, 8.0, 0.4, 0.02),
    ("nano", 6.5, 4.0, 0.6, 0.04),
]

# quant::Precision::transfer_factor() at PAPER_EXPERT_ROW = 4096:
# bytes_per_param relative to fp16's 2.0 B/param.
TRANSFER_FACTORS = [
    1.0,                        # fp16
    (1.0 + 4.0 / 4096.0) / 2.0,  # int8: one f32 absmax per 4096-wide row
    (0.5 + 4.0 / 64.0) / 2.0,    # nf4: one f32 scale per 64-elem block
]

CHUNKS = 4
N_GROUPS = 4
IMPORTANCE_FLOOR = 0.5
TIER_LABELS = ["fp16", "int8", "nf4"]


def chunk_durations(bytes_, pcie_gbps, overhead_ms):
    per = bytes_ / (pcie_gbps * 1e9) * 1e3 / CHUNKS
    return [per if i == 0 else per + overhead_ms for i in range(CHUNKS)]


def window_ms(t_expert_ms):
    lan_transfer = BASE["embed_msg_bytes"] * 8.0 / (BASE["lan_gbps"] * 1e9) * 1e3
    t_main = BASE["t_nonexpert_ms"] + 2.0 * (BASE["lan_lat_ms"] + lan_transfer)
    return N_GROUPS * t_main + (N_GROUPS - 1) * t_expert_ms


def select(tiers, start, deadline, importance):
    # PrecisionController::select with done_chunks = 0, min_tier = 0.
    idx = len(tiers) - 1
    for i, durs in enumerate(tiers):
        if start + sum(durs) <= deadline:
            idx = i
            break
    if importance >= IMPORTANCE_FLOOR:
        idx = min(idx, 1)  # SlackImportance: important experts refuse NF4
    return idx


def tallies():
    out = {}
    for name, t_expert, pcie, _pcie_lat, overhead in CLASSES:
        tiers = [
            chunk_durations(BASE["expert_bytes"] * f, pcie, overhead)
            for f in TRANSFER_FACTORS
        ]
        win = window_ms(t_expert)
        counts = [0, 0, 0]
        for si in range(8):
            start = win * float(si) / 8.0
            for imp in [0.1, 0.3, 0.5, 0.7, 0.9]:
                counts[select(tiers, start, win, imp)] += 1
        for tier, label in enumerate(TIER_LABELS):
            out[f"precision/{name}/loads_{label}"] = float(counts[tier])
    return out


# control::ControlConfig as `od-moe bench` configures it (cli.rs):
# target_p99_ttft_ms 100, replicas 1..=4, dispatch_width 4.
CONTROL_TARGET = 100.0
CONTROL_MIN = 1
CONTROL_MAX = 4
CONTROL_WIDTH = 4

# The scripted 16-epoch drift episode replayed through
# ControlState::observe (one overload ramp, then a calm tail).
EPISODE_P99 = [
    40.0, 90.0, 150.0, 220.0, 260.0, 240.0, 200.0, 150.0,
    110.0, 70.0, 45.0, 40.0, 35.0, 30.0, 30.0, 30.0,
]
EPISODE_QUEUE = [0, 2, 6, 14, 20, 18, 12, 8, 4, 2, 1, 0, 0, 0, 0, 0]
EPISODE_BUSY = [
    0.3, 0.5, 0.8, 0.95, 0.97, 0.9, 0.85, 0.7,
    0.6, 0.45, 0.3, 0.2, 0.2, 0.2, 0.2, 0.2,
]


def classify(p99, queue, live, busy):
    # control::classify — strict comparisons, operands off-boundary.
    cap = live * CONTROL_WIDTH
    if p99 > 1.25 * CONTROL_TARGET or queue > 2 * cap:
        return "over"
    if p99 < 0.5 * CONTROL_TARGET and 2 * queue < cap and busy < 0.5:
        return "calm"
    return "hold"


def control_tallies():
    out = {}
    over = calm = hold = 0
    for ratio in [0.4, 0.8, 1.1, 1.3, 1.6, 2.2]:
        for queue in [0, 2, 6, 12, 24]:
            for busy in [0.2, 0.55, 0.9]:
                kind = classify(ratio * CONTROL_TARGET, queue, 2, busy)
                over += kind == "over"
                calm += kind == "calm"
                hold += kind == "hold"
    out["control/grid_pressure"] = float(over)
    out["control/grid_calm"] = float(calm)
    out["control/grid_hold"] = float(hold)

    # ControlState::observe over the scripted episode, Decision-level
    # counts (an epoch under budget-exhausted pressure counts one
    # relief even where the runtime would hold its relief scale).
    pressure_epochs = calm_epochs = 0
    live = 2
    ups = downs = reliefs = tightens = 0
    for p99, queue, busy in zip(EPISODE_P99, EPISODE_QUEUE, EPISODE_BUSY):
        kind = classify(p99, queue, live, busy)
        delta = 0
        if kind == "over":
            pressure_epochs += 1
            calm_epochs = 0
            if live < CONTROL_MAX:
                delta = 1
            else:
                reliefs += 1
            if pressure_epochs >= 2:
                tightens += 1
        elif kind == "calm":
            calm_epochs += 1
            pressure_epochs = 0
            if calm_epochs >= 2 and live > CONTROL_MIN:
                delta = -1
                calm_epochs = 0
        else:
            pressure_epochs = calm_epochs = 0
        live += delta
        ups += delta > 0
        downs += delta < 0
    out["control/episode_scale_ups"] = float(ups)
    out["control/episode_scale_downs"] = float(downs)
    out["control/episode_reliefs"] = float(reliefs)
    out["control/episode_tightens"] = float(tightens)
    out["control/episode_final_live"] = float(live)
    return out


def main():
    virt = {**tallies(), **control_tallies()}
    doc = {"schema": "odmoe.bench.v1", "virtual": virt}
    if "--check" in sys.argv:
        with open("rust/benches/perf_baseline.json", encoding="utf-8") as f:
            pinned = json.load(f)["virtual"]
        bad = {
            k: (v, pinned.get(k))
            for k, v in virt.items()
            if pinned.get(k) != v
        }
        if bad:
            for k, (want, got) in sorted(bad.items()):
                print(f"MISMATCH {k}: mirror {want} != pinned {got}")
            sys.exit(1)
        print(f"ok: {len(virt)} precision+control metrics match the pinned baseline")
        return
    print(json.dumps(doc, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
