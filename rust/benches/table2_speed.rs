//! Table 2(i)+(ii): inference speed across all seven systems for the four
//! (input, output) configurations, plus the GPU-memory audit.
//! Paper reference (decode averages, tok/s): Transformers 4.89,
//! OD-MoE 3.69, AdapMoE 3.13, Mixtral-Offloading 2.24, llama.cpp 0.82,
//! HOBBIT 0.79, MoE-Infinity 0.69.

mod common;

use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::baselines::{CpuEngine, FullyCachedEngine, OffloadConfig, OffloadEngine};
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::metrics::memory as memaudit;
use odmoe::util::table::Table;
use odmoe::workload::speed::{run_speed_cell, SpeedCell};
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let l = s.rt.cfg.n_layers;
    let (prompts, outs) = s.speed_size();
    let vocab = s.rt.cfg.vocab_size as u32;

    // Engines in the paper's column order.
    let mut engines: Vec<Box<dyn Engine + '_>> = vec![
        Box::new(OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::mixtral_offloading(l))?),
        Box::new(OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::moe_infinity(l))?),
        Box::new(OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::hobbit(l))?),
        Box::new(OffloadEngine::new(&s.rt, ws.clone(), OffloadConfig::adapmoe(l))?),
        Box::new(FullyCachedEngine::new(&s.rt, ws.clone())?),
        Box::new(CpuEngine::new(&s.rt, ws.clone())?),
        Box::new(OdMoeEngine::new(&s.rt, ws.clone(), OdMoeConfig::default())?),
    ];
    let names = ["MxOff", "MoE-Inf", "HOBBIT", "AdapMoE", "Transformers", "llama.cpp", "OD-MoE"];
    let paper_decode = [2.2375, 0.6875, 0.7850, 3.1300, 4.8900, 0.8225, 3.6925];

    println!("# Table 2(i) — inference speed (paper-scale, 32-layer equivalent)\n");
    for metric in ["TTFT (ms)", "Decode tok/s", "Output tok/s"] {
        println!("## {metric}");
        let mut table = {
            let mut h: Vec<String> = vec!["config".into()];
            h.extend(names.iter().map(|n| n.to_string()));
            let refs: Vec<&str> = h.iter().map(|x| x.as_str()).collect();
            Table::new(&refs)
        };
        // Cells per engine per config.
        let mut per_cfg: Vec<Vec<SpeedCell>> = Vec::new();
        for e in engines.iter_mut() {
            let mut cells = Vec::new();
            for (input_len, corpus_seed) in [(16usize, 0x51u64), (128, 0x52)] {
                let corpus = Corpus::generate(s.seed ^ corpus_seed, prompts, input_len, vocab);
                for &out in &outs {
                    cells.push(run_speed_cell(e.as_mut(), &corpus, out)?);
                }
            }
            per_cfg.push(cells);
        }
        let n_cfg = per_cfg[0].len();
        for c in 0..n_cfg {
            let cell0 = &per_cfg[0][c];
            let mut row = vec![format!("({}, {})", cell0.input_len, cell0.output_len)];
            for cells in &per_cfg {
                let cell = &cells[c];
                row.push(match metric {
                    "TTFT (ms)" => format!("{:.0}", cell.scaled.mean_ttft_ms()),
                    "Decode tok/s" => format!("{:.3}", cell.scaled.decode_tps()),
                    _ => format!("{:.3}", cell.scaled.output_tps()),
                });
            }
            table.row(&row);
        }
        // Average row + paper reference for decode.
        let mut avg_row = vec!["average".to_string()];
        for cells in &per_cfg {
            let vals: Vec<f64> = cells
                .iter()
                .map(|c| match metric {
                    "TTFT (ms)" => c.scaled.mean_ttft_ms(),
                    "Decode tok/s" => c.scaled.decode_tps(),
                    _ => c.scaled.output_tps(),
                })
                .collect();
            let avg = vals.iter().sum::<f64>() / vals.len() as f64;
            avg_row.push(if metric == "TTFT (ms)" {
                format!("{avg:.0}")
            } else {
                format!("{avg:.3}")
            });
        }
        table.row(&avg_row);
        if metric == "Decode tok/s" {
            let mut p_row = vec!["paper avg".to_string()];
            p_row.extend(paper_decode.iter().map(|v| format!("{v:.4}")));
            table.row(&p_row);
        }
        table.print();
        println!();
    }

    println!("# Table 2(ii) — GPU memory (GB)\n");
    let p = HardwareProfile::rtx3090();
    let mut table = Table::new(&["system", "ours", "paper"]);
    for (audit, paper) in [
        (memaudit::offloading("MxOff", &p, 64, 0.143, 0.35), "11"),
        (memaudit::offloading("MoE-Inf", &p, 42, 0.5, 0.35), "21.5"),
        (memaudit::offloading("HOBBIT", &p, 110, 0.25, 0.35), "22"),
        (memaudit::offloading("AdapMoE", &p, 52, 0.143, 0.35), "8"),
        (memaudit::fully_cached(&p), "180"),
        (memaudit::cpu_only(), "N/A"),
        (memaudit::odmoe(&p, 8), "60"),
    ] {
        table.row(&[
            audit.system.to_string(),
            format!("{:.1}", audit.total_gb()),
            paper.to_string(),
        ]);
    }
    table.print();
    Ok(())
}
