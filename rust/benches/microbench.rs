//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): PJRT dispatch
//! latency per artifact, full decode-step latency, simulator event
//! throughput, quantization throughput.

mod common;

use odmoe::cluster::{Cluster, HardwareProfile};
use odmoe::engine::ModelState;
use odmoe::quant;
use odmoe::util::bench;

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let cfg = s.rt.cfg.clone();

    bench::header();

    // --- PJRT dispatch costs -------------------------------------------
    let mut state = ModelState::new(&s.rt, ws.clone())?;
    let k_cache = vec![0f32; cfg.max_seq_len * cfg.n_kv_heads * cfg.head_dim];
    let x = vec![0.1f32; cfg.d_model];
    let h = vec![0.1f32; cfg.d_model];

    // Raw runtime calls via a device model.
    let dm = odmoe::runtime::DeviceModel::upload(&s.rt, &ws)?;
    bench::run("pjrt: main_block_decode (1 layer)", 30, 5, || {
        s.rt.main_block_decode(&dm, 0, &x, &k_cache, &k_cache, 3).unwrap();
    })
    .print();
    bench::run("pjrt: expert_ffn t=1", 30, 10, || {
        s.rt.expert_ffn(&dm, 0, 0, &h, 1).unwrap();
    })
    .print();
    bench::run("pjrt: lm_head", 30, 10, || {
        s.rt.lm_head(&dm, &x).unwrap();
    })
    .print();

    // --- Full decode step (12 layers + experts + lm head). --------------
    let mut tok = 3u32;
    bench::run("engine: full decode step (12 layers)", 10, 2, || {
        if state.pos + 1 >= cfg.max_seq_len {
            state.reset();
        }
        tok = state.decode_step(tok).unwrap().token_out;
    })
    .print();

    // --- Simulator event throughput. -------------------------------------
    bench::run("sim: 1k resource bookings", 50, 10, || {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 8);
        for i in 0..1000 {
            let w = i % 8;
            c.expert_load(w, i as f64, 1e6);
        }
        std::hint::black_box(c.lan.free_at());
    })
    .print();

    // --- Quantization throughput (shadow build cost). --------------------
    let w = ws.experts[0][0].w1.clone();
    bench::run("quant: int8 fake-quant 8k params", 30, 20, || {
        std::hint::black_box(quant::fake_quant_int8(&w, cfg.d_ff));
    })
    .print();
    bench::run("quant: nf4 fake-quant 8k params", 30, 20, || {
        std::hint::black_box(quant::fake_quant_nf4(&w));
    })
    .print();

    println!(
        "\ntotal PJRT executions this run: {}  | host bytes uploaded: {:.1} MB",
        s.rt.stats.executions.get(),
        s.rt.stats.host_bytes_uploaded.get() as f64 / 1e6
    );
    Ok(())
}
