//! Fig. 3: SEP expert-selection recall vs output-token index, for shadow
//! precisions {NF4, INT8, FP16} x alignment setups {unaligned, token-only,
//! token+KV}. Paper reference: aligned overall recall 0.9567 / 0.9734 /
//! 0.9994; unaligned curves decay with token index.

mod common;

use odmoe::model::Precision;
use odmoe::predictor::AlignmentConfig;
use odmoe::util::table::{sparkline, Table};
use odmoe::workload::{recall, Corpus};

fn main() -> anyhow::Result<()> {
    let s = common::Setup::new();
    let ws = s.weights();
    let (prompts, out_tokens) = s.recall_size();
    let corpus = Corpus::generate(s.seed ^ 1, prompts, 16, s.rt.cfg.vocab_size as u32);

    println!("# Fig. 3 — recall vs token index (Q={prompts}, N={out_tokens})\n");
    let mut table = Table::new(&[
        "shadow", "alignment", "recall@1", "recall@mid", "recall@last", "overall", "curve",
    ]);
    for p in [Precision::Nf4, Precision::Int8, Precision::Fp16] {
        for (label, align) in [
            ("unaligned", AlignmentConfig::none()),
            ("token-only", AlignmentConfig::token_only()),
            ("token+KV", AlignmentConfig::every_iteration()),
        ] {
            let stats = recall::sep_recall(&s.rt, &ws, p, align, &corpus, out_tokens)?;
            let curve = stats.curve();
            let mid = curve.len() / 2;
            table.row(&[
                p.label().into(),
                label.into(),
                format!("{:.4}", curve[0]),
                format!("{:.4}", curve[mid]),
                format!("{:.4}", curve[curve.len() - 1]),
                format!("{:.4}", stats.recall()),
                sparkline(&curve),
            ]);
        }
    }
    table.print();
    println!("\npaper: aligned overall = 0.9567 (nf4) / 0.9734 (int8) / 0.9994 (fp16);");
    println!("unaligned decays from ~1.0 toward ~0.3; token-only sits between.");
    Ok(())
}
