//! Shared setup for the figure/table benches.
//!
//! Workload sizes are scaled down from the paper's (Q=100, N=512) so the
//! whole `cargo bench` suite finishes in minutes on one CPU core; set
//! `ODMOE_BENCH_SCALE=paper` for larger sweeps. Every bench prints the
//! paper's reference values next to ours — shape comparison is the goal
//! (see EXPERIMENTS.md).

use odmoe::model::WeightStore;
use odmoe::Runtime;

pub struct Setup {
    pub rt: Runtime,
    pub seed: u64,
}

impl Setup {
    pub fn new() -> Self {
        let rt = Runtime::load_default().expect("run `make artifacts` first");
        Self { rt, seed: 42 }
    }

    pub fn weights(&self) -> WeightStore {
        WeightStore::generate(&self.rt.cfg, self.seed)
    }

    /// (prompts, out_tokens) for recall-style sweeps.
    pub fn recall_size(&self) -> (usize, usize) {
        if big() {
            (16, 256)
        } else {
            (4, 48)
        }
    }

    /// (prompts_per_length, out_tokens list) for speed sweeps.
    pub fn speed_size(&self) -> (usize, Vec<usize>) {
        if big() {
            (4, vec![64, 256])
        } else {
            (1, vec![24])
        }
    }
}

pub fn big() -> bool {
    std::env::var("ODMOE_BENCH_SCALE").as_deref() == Ok("paper")
}
